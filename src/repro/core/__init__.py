"""The paper's contribution: scalable time-range k-core queries (TCQ).

Public API:
  TemporalGraph      — host-side ArrayTEL (build / epoch-versioned
                       incremental append / ship)
  TCQEngine          — compiled query engine for one graph (streaming:
                       update_graph installs new epochs in place)
  TCQService         — continuous serving runtime: window-clustered lane
                       pools, mid-flight admission, epoch-pinned snapshots
  CoreCache          — TTI-keyed core-result cache (cross-request reuse,
                       incremental invalidation on ingest)
  temporal_kcore_query — one-shot convenience wrapper
  tcd / tcd_batch    — the TCD operation (truncate + frontier peel + TTI)
  brute_force_query  — oracle
  PHCIndex / iphc_query — the paper's baseline (Algorithm 1)
  WriteAheadLog      — durable streaming: append-only CRC-checked journal
                       (TCQService(wal_dir=...) / TCQService.recover)
"""

from repro.core.baseline import PHCIndex, iphc_query  # noqa: F401
from repro.core.corecache import CacheView, CoreCache  # noqa: F401
from repro.core.engine import (WavePipeline, pack_alive_u32,  # noqa: F401
                               unpack_alive_u32)
from repro.core.graph import (DeviceTEL, GraphIngestError,  # noqa: F401
                              TemporalGraph)
from repro.core.oracle import brute_force_query, peel_window  # noqa: F401
from repro.core.otcd import TCQEngine, temporal_kcore_query  # noqa: F401
from repro.core.results import CoreResult, QueryStats, TCQResult  # noqa: F401
from repro.core.scheduler import (EmptyStaircase, QueryState,  # noqa: F401
                                  autotune_wave)
from repro.core.service import (TCQService, TCQTicket,  # noqa: F401
                                cluster_windows)
from repro.core.tcd import TCDResult, coreness, tcd, tcd_batch  # noqa: F401
from repro.core.wal import (SnapshotCorruption, WALError,  # noqa: F401
                            WALRecord, WALReplayError, WriteAheadLog)
from repro.core.wave import (DegradationLadder,  # noqa: F401
                             ResilienceConfig, make_oracle_step_fn)
