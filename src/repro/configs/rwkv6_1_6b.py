"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892] — attention-free, data-dependent
decay linear recurrence; O(1)-state decode => runs the long_500k cell."""
from repro.models.config import ModelConfig, RWKVCfg

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=7168, vocab=65_536,
    pos="none", tie_embeddings=False,
    rwkv=RWKVCfg(head_dim=64, decay_lora=64, mix_lora=32),
    max_seq=1_048_576, supports_long_context=True,
    notes="attention-free; TCQ technique inapplicable (no attention sharding)",
)
