"""TCQ serving launcher: the paper's system as a *streaming service* —
open-loop query arrivals over a temporal graph that keeps growing while
queries run, served by ``TCQService`` (window-clustered lane pools,
mid-flight admission, epoch-pinned snapshots).

    PYTHONPATH=src python -m repro.launch.serve --vertices 2000 \
        --edges 30000 --requests 16 --qps 4 [--ingest-batches 4] \
        [--distributed] [--combine rs_ag]

The driver is open-loop: request arrival times come from a seeded
exponential inter-arrival process at ``--qps`` and are injected by the
service's ``poll`` hook whenever lanes free up — arrivals during a pool
run are admitted mid-flight when their window fits, otherwise they queue
for the next pool.  Edge ingestion batches land on their own schedule
(between arrivals), each producing a new TEL epoch; queries always
answer over the snapshot current at their admission.  Reported: p50 /
p95 / p99 submit-to-completion latency, sustained qps, mean pool
occupancy, and the epoch count ingested while serving.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def serve_stream(graph, requests, *, qps: float, ingest=None,
                 wave="auto", depth: int = 2, cluster_gap: int = 0,
                 warm: bool = True):
    """Drive a TCQService with an open-loop arrival schedule.

    ``requests`` is a list of dicts with an ``arrive_s`` offset
    (``TCQRequestStream.open_loop`` format); ``ingest`` is an optional
    iterator of (u, v, t) arrival batches pushed one per poll interval.
    Returns (service, served tickets, wall seconds).
    """
    from repro.core import TCQService

    # retain_snapshots=False: a long-lived server must not keep one O(E)
    # graph snapshot alive per ingested epoch through its ticket history
    svc = TCQService(graph, wave=wave, depth=depth, cluster_gap=cluster_gap,
                     retain_snapshots=False)
    if warm and requests:
        # warm the compile caches so latency percentiles measure the
        # steady state, not first-shape compilation
        r0 = requests[0]
        svc.submit({k: r0[k] for k in ("k", "ts", "te")})
        svc.run_until_idle()
        svc.completed.clear()
        svc.pool_log.clear()
    queue = sorted(requests, key=lambda r: r["arrive_s"])
    ingest = iter(ingest) if ingest is not None else None
    state = {"i": 0, "epochs": 0, "t0": time.perf_counter()}

    def poll(s):
        now = time.perf_counter() - state["t0"]
        while state["i"] < len(queue) and queue[state["i"]]["arrive_s"] <= now:
            s.submit(queue[state["i"]])
            state["i"] += 1
        if ingest is not None and state["epochs"] < state["i"]:
            # one ingestion batch per served arrival tranche: edges land
            # continuously while queries are in flight
            try:
                u, v, t = next(ingest)
                s.push_edges(u, v, t)
                state["epochs"] += 1
            except StopIteration:
                pass

    served = []
    while state["i"] < len(queue) or svc.pending:
        out = svc.run_until_idle(poll)
        served.extend(out)
        if state["i"] < len(queue):
            # idle before the next arrival: sleep to its arrival time
            nxt = queue[state["i"]]["arrive_s"] - (
                time.perf_counter() - state["t0"])
            if nxt > 0:
                time.sleep(min(nxt, 0.05))
    wall = time.perf_counter() - state["t0"]
    return svc, served, wall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=2_000)
    ap.add_argument("--edges", type=int, default=30_000)
    ap.add_argument("--span", type=int, default=16_384)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--qps", type=float, default=4.0,
                    help="open-loop arrival rate (requests/sec)")
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--wave", default="auto")
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--ingest-batches", type=int, default=4,
                    help="edge arrival batches streamed during serving")
    ap.add_argument("--distributed", action="store_true",
                    help="shard_map engine on the local host mesh")
    ap.add_argument("--combine", default="rs_ag",
                    choices=["psum", "rs_ag"])
    args = ap.parse_args()

    from repro.data import TCQRequestStream
    from repro.graphs import EdgeStream, powerlaw_temporal

    g = powerlaw_temporal(args.vertices, args.edges, args.span, seed=3)
    lo, hi = g.span

    if args.distributed:
        from repro.core.distributed import DistributedTCQ
        from repro.launch.mesh import make_host_mesh

        reqs = list(TCQRequestStream(lo, hi, k=args.k,
                                     span=max(64, args.span // 20),
                                     seed=0).requests(args.requests))
        mesh = make_host_mesh()
        eng = DistributedTCQ(g, mesh, combine=args.combine)
        t0 = time.perf_counter()
        alive, tlo, thi, ne, iters = eng.query_wave(
            [r["ts"] for r in reqs], [r["te"] for r in reqs], args.k)
        dt = time.perf_counter() - t0
        for i, r in enumerate(reqs):
            print(f"req#{r['id']:03d} window=[{r['ts']},{r['te']}] -> "
                  f"top-core TTI=[{int(tlo[i])},{int(thi[i])}] "
                  f"|E|={int(ne[i])}")
        print(f"[serve] distributed wave of {len(reqs)} on mesh "
              f"{dict(zip(mesh.axis_names, mesh.devices.shape))}: "
              f"{dt:.3f}s ({int(iters)} peel iterations)")
        return

    reqs = list(TCQRequestStream(lo, hi, k=args.k,
                                 span=max(64, args.span // 20),
                                 seed=0).open_loop(args.requests, args.qps))
    future = powerlaw_temporal(args.vertices, max(args.edges // 8, 64),
                               args.span // 4, seed=5)
    arrivals = ((u, v, t + hi) for u, v, t in
                EdgeStream.replay(future, max(1, args.ingest_batches)))

    wave = args.wave if args.wave == "auto" else int(args.wave)
    svc, served, wall = serve_stream(g, reqs, qps=args.qps, ingest=arrivals,
                                     wave=wave, depth=args.depth)
    lat = np.array([tk.latency_s for tk in served])
    occ = [p["occupancy"] for p in svc.pool_log if p["device_steps"]]
    mid = sum(p["admitted_midflight"] for p in svc.pool_log)
    for tk in sorted(served, key=lambda tk: tk.id)[:8]:
        print(f"req#{tk.id:03d} k={tk.k} window=[{tk.ts},{tk.te}] "
              f"epoch={tk.epoch} -> {len(tk.result)} cores "
              f"({1e3 * tk.latency_s:.1f} ms)")
    print(f"\n[serve] {len(served)} requests in {wall:.2f}s "
          f"({len(served) / wall:.2f} qps sustained, target {args.qps}) "
          f"over {svc.epoch} ingested epochs")
    print(f"[serve] latency p50 {1e3 * np.quantile(lat, .5):.1f} ms | "
          f"p95 {1e3 * np.quantile(lat, .95):.1f} ms | "
          f"p99 {1e3 * np.quantile(lat, .99):.1f} ms")
    print(f"[serve] {len(svc.pool_log)} pools, "
          f"mean occupancy {np.mean(occ) if occ else 0:.1f} cells/step, "
          f"{mid} mid-flight admissions")


if __name__ == "__main__":
    main()
