"""Result containers for temporal k-core queries."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CoreResult:
    """One distinct temporal k-core.

    Identity is its TTI (paper Property 2: cores are identical iff their
    tightest time intervals are equal, for a fixed k and graph).
    """

    k: int
    tti: Tuple[int, int]
    vertices: np.ndarray  # sorted vertex ids
    n_edges: int

    @property
    def span(self) -> int:
        return self.tti[1] - self.tti[0]

    @property
    def n_vertices(self) -> int:
        return int(self.vertices.size)

    def __repr__(self) -> str:  # compact for logs
        return (f"Core(k={self.k}, tti=[{self.tti[0]},{self.tti[1]}], "
                f"|V|={self.n_vertices}, |E|={self.n_edges})")


@dataclasses.dataclass
class QueryStats:
    """Per-query schedule/pipeline counters.

    For queries served through ``TCQEngine.query_batch`` the pipeline is
    shared, so the device-side counters (device_steps, host_syncs,
    bytes_synced, peel_iters, lane_refills, occupancy, wall_time_s)
    describe the whole batch and are reported identically on every
    member query; schedule counters (cells_*, pruned_*, duplicates)
    remain query-local.
    """

    n_timestamps: int = 0
    cells_total: int = 0          # n*(n+1)/2 schedule cells (unique-ts space)
    cells_evaluated: int = 0      # TCD operations actually executed
    cells_trivial: int = 0        # skipped host-side (provably empty)
    cells_cached: int = 0         # resolved from the TTI core cache
    duplicates: int = 0           # re-induced cores (0 for serial OTCD)
    por_triggers: int = 0
    pou_triggers: int = 0
    pol_triggers: int = 0
    pruned_por: int = 0           # cells pruned by each rule
    pruned_pou: int = 0
    pruned_pol: int = 0
    pruned_empty: int = 0
    device_steps: int = 0
    host_syncs: int = 0           # blocking device->host sync points
    bytes_synced: int = 0         # total device->host result payload
    lane_refills: int = 0         # in-place lane buffer refills (wave mode)
    admissions: int = 0           # queries admitted mid-flight (live pool)
    peel_iters: int = 0           # shared fixpoint iterations (wave mode)
    window_edges: int = 0         # edges in the windowed TEL actually peeled
    occupancy: float = 0.0        # mean occupied lanes per device step (wave)
    batch_size: int = 0           # queries sharing the pipeline (query_batch)
    wall_time_s: float = 0.0
    collective_bytes: int = 0     # degree-combine wire bytes (sharded pools)
    shard_occupancy: Optional[List[float]] = None  # per-lane-shard occupancy

    def absorb_pool(self, pool_stats: "QueryStats", *, window_edges: int,
                    batch_size: int) -> None:
        """Copy the shared lane pool's device-side counters onto one
        member query's stats (used by ``query_batch`` and the streaming
        service — the single place the pool->member field list lives)."""
        self.window_edges = window_edges
        self.batch_size = batch_size
        self.device_steps = pool_stats.device_steps
        self.host_syncs = pool_stats.host_syncs
        self.bytes_synced = pool_stats.bytes_synced
        self.peel_iters = pool_stats.peel_iters
        self.lane_refills = pool_stats.lane_refills
        self.admissions = pool_stats.admissions
        self.occupancy = pool_stats.occupancy
        self.collective_bytes = pool_stats.collective_bytes
        self.shard_occupancy = pool_stats.shard_occupancy

    @property
    def pruned_total(self) -> int:
        return self.pruned_por + self.pruned_pou + self.pruned_pol

    def pruned_pct(self) -> float:
        if self.cells_total == 0:
            return 0.0
        return 100.0 * self.pruned_total / self.cells_total


@dataclasses.dataclass
class TCQResult:
    cores: List[CoreResult]
    stats: QueryStats

    def by_tti(self) -> Dict[Tuple[int, int], CoreResult]:
        return {c.tti: c for c in self.cores}

    def filter_span(self, min_span: Optional[int] = None,
                    max_span: Optional[int] = None) -> "TCQResult":
        """Paper §6.2 time-span constraint, applied on the fly or post-hoc."""
        out = [c for c in self.cores
               if (min_span is None or c.span >= min_span)
               and (max_span is None or c.span <= max_span)]
        return TCQResult(out, self.stats)

    def top_n_shortest_span(self, n: int) -> List[CoreResult]:
        return sorted(self.cores, key=lambda c: (c.span, c.tti))[:n]

    def __len__(self) -> int:
        return len(self.cores)
