"""Device-resident multi-tenant wave pipeline — the engine behind
``mode="wave"`` and ``TCQEngine.query_batch``.

The pipeline is split in two layers:

* **Per-query schedule bookkeeping** lives in ``core/scheduler.py``: a
  :class:`~repro.core.scheduler.QueryState` owns one query's row cursors,
  IntervalSet pruning (Rules 1–3), empty-cell staircase, warm-start rows
  (Theorem 1) and TTI dedup (Property 2).

* **The lane pool** (this module) owns the device side: one persistent
  [W, V] bool buffer whose rows ("lanes") each peel one schedule cell per
  fused :func:`wave_step`.  The pool draws ready cells round-robin from
  *any number* of QueryStates, so lanes freed by one query's draining tail
  are immediately refilled with another query's cells — the fused step
  stays full under concurrent traffic instead of decaying with a single
  query's schedule.  ``k``/``h`` ride along as per-lane [W] vectors, so
  one step carries cells from queries with different thresholds.  The
  pool is a *live queue*: ``run_pool``'s optional ``admit`` hook is
  polled whenever lanes free, so a streaming service
  (``core/service.py``) can admit newly arrived queries mid-flight with
  no drain barrier between request batches.

Device mechanics (carried over from the single-query pipeline, measured
3.7x over the seed stepwise engine, which was retired after PR 2):

* **Persistent lane state** — the [W, V] buffer is donated through every
  ``wave_step``; exhausted lanes are refilled *in place* with
  ``lax.dynamic_update_index_in_dim`` (cold rows from all-ones, warm rows
  from the owning query's best completed row-initial core), so lane masks
  never round-trip through the host.

* **Fused step + packed result transfer** — truncate + frontier peel
  (edge activity carried in the fixpoint loop), the TTI reduction,
  per-lane stats, and a ``uint32`` bitmask pack [W, ceil(V/32)] are one
  jitted program; each step syncs one packed array plus four small [W]
  vectors, and core vertex sets are decoded host-side in one deferred
  bulk ``np.unpackbits`` per query.

* **Depth-D slot ring** — D lane buffers (default 2) cycle through
  dispatch: while slots B..D execute on device, the host retires slot A
  (pruning, packed collection), reassembles and re-dispatches it, then
  blocks on the next slot's scalars.  Pruning observed by an in-flight
  slot is thus up to D-1 steps stale — safe, because a stale lane at
  worst re-induces a core its query already found, and such duplicates
  are removed by TTI identity (Property 2) and counted per query.

* **Kernel degree path** — the Pallas ``banded_segsum`` closures (and
  their k_max band analysis) are built once per ``TCQEngine`` by the
  dispatching wrapper: compiled Pallas on TPU, XLA segment-sum elsewhere.

The pipeline peels against a *windowed* TEL (``TCQEngine._window_tel``,
epoch-keyed so graph updates can never serve stale truncations): for a
pool, one TEL truncated to the union window serves every lane — per-lane
``ts``/``te`` keep each query's exact windowed semantics, so cross-query
packing is bit-identical to running each query alone.  The streaming
service clusters co-admitted requests by window overlap and runs one
pool per cluster, so each pool's TEL stays tight.
"""

from __future__ import annotations

import functools
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.graph import DeviceTEL
from repro.core.results import CoreResult, QueryStats
from repro.core.scheduler import QueryState, RowCursor
# The device step itself (StepResult, the XLA-composite wave_step, the
# fused-Pallas dispatcher and the bitmask pack helpers) lives in
# core/wave.py next to the peel loop; re-exported here because the
# engine is their primary consumer and external callers import them
# from this module.
from repro.core.wave import (StepResult, make_wave_step_fn,  # noqa: F401
                             pack_alive_u32, packed_width,
                             unpack_alive_u32, wave_step)


# ---------------------------------------------------------- lane refills
@functools.partial(jax.jit, donate_argnums=(0,))
def _set_lane(buf: jnp.ndarray, li, row: jnp.ndarray) -> jnp.ndarray:
    """In-place (donated) overwrite of lane ``li`` with a device row."""
    return lax.dynamic_update_index_in_dim(buf, row, li, 0)


@functools.partial(jax.jit, static_argnames=("value",), donate_argnums=(0,))
def _fill_lane(buf: jnp.ndarray, li, value: bool) -> jnp.ndarray:
    """In-place (donated) fill of lane ``li`` with a constant mask."""
    row = jnp.full((buf.shape[1],), value, dtype=bool)
    return lax.dynamic_update_index_in_dim(buf, row, li, 0)


# -------------------------------------------------------------- lane pool
class _Slot:
    """One ring stage: a device lane buffer + its in-flight step.

    ``lanes[li]`` holds the (QueryState, RowCursor) the lane is serving,
    or None when free; ``dirty`` marks lanes holding a stale (dead) mask.
    """

    __slots__ = ("buf", "lanes", "dirty", "inflight",
                 "_params_np", "_params_dev")

    def __init__(self, wave: int, num_vertices: int, buf=None):
        # callers may hand in a pre-placed buffer (the sharded pipeline
        # allocates its slabs with an explicit mesh sharding)
        self.buf = (jnp.zeros((wave, num_vertices), dtype=bool)
                    if buf is None else buf)
        self.lanes: List[Optional[Tuple[QueryState, RowCursor]]] = \
            [None] * wave
        self.dirty: set = set()
        self.inflight: Optional[StepResult] = None
        # committed (ts, te, k, h) cache for sharded pipelines: the host
        # vectors + their device placements from the last dispatch
        self._params_np = None
        self._params_dev = None


class WavePipeline:
    """Depth-D software-pipelined lane pool over :func:`wave_step`.

    :meth:`run_pool` serves any number of QueryStates through one shared
    lane buffer; :meth:`run` is the single-query wrapper used by
    ``TCQEngine.query(mode="wave")``.
    """

    def __init__(self, tel: DeviceTEL, num_vertices: int,
                 seg_pair, seg_vert, wave: int, depth: int = 2,
                 step_fn=None):
        self.tel = tel
        self.num_vertices = num_vertices
        self.seg_pair = seg_pair
        self.seg_vert = seg_vert
        self.wave = wave
        self.depth = max(1, int(depth))
        # the device step: a prebuilt ``make_wave_step_fn`` closure (the
        # engine pins one per windowed TEL so the fused kernel's host-side
        # band analysis is never rebuilt per pipeline), else the default
        # dispatch — fused Pallas on TPU, XLA composite elsewhere.  The
        # lane buffer is donated through every step either way.
        if step_fn is None:
            step_fn = make_wave_step_fn(tel, num_vertices,
                                        seg_pair=seg_pair, seg_vert=seg_vert,
                                        donate=True)
        self._step = step_fn

    # ------------------------------------------------- subclass seams
    # The sharded pipeline (core/distributed.py) overrides these four
    # hooks to place slot buffers on a mesh, batch lane refills into two
    # device calls, and account per-shard occupancy + collective bytes.
    # The base implementations reproduce the historical single-device
    # behavior exactly (same jitted calls in the same order).
    def _new_slot(self) -> "_Slot":
        return _Slot(self.wave, self.num_vertices)

    def _refill_lanes(self, buf, sets, fills):
        """Apply lane refills to ``buf``: ``sets`` is [(lane, device
        row)] warm starts, ``fills`` is [(lane, bool)] constant masks.
        Lanes are disjoint across the two lists, so application order
        between them is irrelevant."""
        for li, value in fills:
            buf = _fill_lane(buf, li, value)
        for li, row in sets:
            buf = _set_lane(buf, li, row)
        return buf

    def _record_occupied(self, occupied: List[int]) -> None:
        pass

    def _warm_row(self, res: StepResult, packed: np.ndarray, li: int):
        """Thunk producing lane ``li``'s [V] alive row for warm-start
        reuse (only materialized when the cell becomes the row's best
        warm start).  Sharded pipelines override this: slicing a
        mesh-sharded buffer is an eager cross-device gather, so they
        unpack the already-fetched host bitmask instead."""
        return lambda: res.alive[li]

    def _commit_params(self, slot: "_Slot", params):
        """Place the per-lane (ts, te, k, h) host vectors for the step.
        Sharded pipelines override this to commit to the lane axis once
        per refill instead of once per step."""
        return tuple(jnp.asarray(p) for p in params)

    def _finish_pool(self, pool_stats: QueryStats) -> None:
        pass

    def run(self, uts: np.ndarray, k: int, h: int, prune: bool,
            stats: QueryStats, cache=None
            ) -> Dict[Tuple[int, int], CoreResult]:
        """Single-query entry: one QueryState, same stats object for both
        the query's and the pool's counters.  ``cache`` is an optional
        corecache.CacheView — hits skip lanes, peels are inserted."""
        qs = QueryState(uts, k, h, prune, stats, cache=cache)
        self.run_pool([qs], stats)
        return qs.decode_results(self.num_vertices)

    def run_pool(self, states: List[QueryState], pool_stats: QueryStats,
                 admit: Optional[Callable[[], List[QueryState]]] = None
                 ) -> None:
        """Drain a live pool of queries through the shared lane buffer.

        Cells are claimed round-robin across queries, so one device step
        mixes lanes from many (k, h, window) queries; each query's results
        accumulate in its own QueryState (bit-identical to running it
        alone — packing changes lane placement, never pruning soundness,
        because every QueryState keeps private pruning/dedup state).

        ``admit`` turns the fixed state list into a *live queue*: it is
        polled every time a slot reassembles (i.e. whenever lanes free
        up) and may hand back freshly admitted QueryStates, which join
        the claimable rotation immediately — mid-flight admission with
        no drain barrier.  The pool only ends once every in-flight lane
        has retired *and* ``admit`` comes back empty, so a streaming
        service can keep the fused step full across request arrivals.

        Admission is earliest-deadline-first: cells are claimed from the
        live state with the smallest ``(deadline, priority)`` key, with
        the original round-robin rotation breaking ties — so best-effort
        pools (every deadline inf) schedule exactly as before, while a
        deadline-carrying pool drains urgent queries first.  A state
        whose ``cancelled`` flag is set (deadline timeout, client
        cancellation — see ``TCQService``) stops claiming immediately
        and its in-flight lanes are *reclaimed mid-pool*: freed at the
        next assemble/retire without result feedback, ready for other
        queries' cells.
        """
        W = self.wave
        claimable = deque(s for s in states if s.n > 0 and not s.cancelled)
        occupied_total = 0

        def refill() -> None:
            if admit is None:
                return
            for s in admit():
                if s.n > 0 and not s.cancelled:
                    claimable.append(s)
                    pool_stats.admissions += 1

        def claim() -> Optional[Tuple[QueryState, RowCursor]]:
            while claimable:
                bi, best = 0, claimable[0]._edf
                for i, s2 in enumerate(claimable):
                    k2 = s2._edf
                    if k2 < best:
                        bi, best = i, k2
                claimable.rotate(-bi)       # EDF: walk to an urgent state
                s = claimable[0]
                if s.cancelled:
                    claimable.popleft()
                    continue
                row = s.claim()
                if row is not None:
                    claimable.rotate(-1)    # round-robin among EDF ties
                    return s, row
                claimable.popleft()         # drained: nothing pending
            return None

        def release_cancelled(slot: _Slot) -> None:
            """Reclaim lanes whose query was cancelled since dispatch:
            the lane frees (dirty — its mask is garbage to everyone
            else) and the state's live-lane count drops so ``done``
            can resolve without result feedback."""
            for li in range(W):
                lane = slot.lanes[li]
                if lane is not None and lane[0].cancelled:
                    lane[0].live_rows -= 1
                    slot.lanes[li] = None
                    slot.dirty.add(li)

        def assemble(slot: _Slot) -> None:
            """Claim ready cells into free lanes and refill their masks."""
            refill()
            release_cancelled(slot)
            sets: List[Tuple[int, jnp.ndarray]] = []
            fills: List[Tuple[int, bool]] = []
            for li in range(W):
                if slot.lanes[li] is not None:
                    continue
                got = claim()
                if got is None:
                    break
                s, row = got
                slot.lanes[li] = (s, row)
                warm = s.warm_start(row)
                if warm is not None:
                    sets.append((li, warm))
                else:
                    fills.append((li, True))
                slot.dirty.discard(li)
                pool_stats.lane_refills += 1
            # lanes that died and were not re-claimed: zero once so the
            # shared fixpoint loop never spends iterations peeling them
            for li in sorted(slot.dirty):
                fills.append((li, False))
            slot.dirty.clear()
            if sets or fills:
                slot.buf = self._refill_lanes(slot.buf, sets, fills)

        def dispatch(slot: _Slot) -> None:
            occupied = [li for li in range(W)
                        if slot.lanes[li] is not None]
            if not occupied:
                slot.inflight = None
                return
            # stage per-lane params in python lists: element stores into
            # numpy arrays cost ~100ns each and this runs per step
            ts_l, te_l = [0] * W, [-1] * W      # empty window for padding
            k_l, h_l = [1] * W, [1] * W
            for li in occupied:
                s, row = slot.lanes[li]
                ts_l[li], te_l[li] = s.window(row)
                k_l[li], h_l[li] = s.k, s.h
                s.stats.cells_evaluated += 1
            ts_arr = np.array(ts_l, np.int32)
            te_arr = np.array(te_l, np.int32)
            k_arr = np.array(k_l, np.int32)
            h_arr = np.array(h_l, np.int32)
            slot.inflight = self._step(
                slot.buf, *self._commit_params(
                    slot, (ts_arr, te_arr, k_arr, h_arr)))
            slot.buf = slot.inflight.alive   # donated through; new handle
            pool_stats.device_steps += 1
            nonlocal occupied_total
            occupied_total += len(occupied)
            self._record_occupied(occupied)

        def retire(slot: _Slot) -> None:
            res = slot.inflight
            slot.inflight = None
            packed, lo, hi, ne, it = jax.device_get(
                (res.packed, res.tti_lo, res.tti_hi, res.n_edges, res.iters))
            pool_stats.host_syncs += 1
            pool_stats.bytes_synced += (packed.nbytes + lo.nbytes + hi.nbytes
                                        + ne.nbytes + it.nbytes)
            pool_stats.peel_iters += int(it)
            # python scalars up front: numpy scalar indexing costs ~100ns
            # per element and this loop touches four per occupied lane
            lo_l, hi_l, ne_l = lo.tolist(), hi.tolist(), ne.tolist()
            for li in range(W):
                lane = slot.lanes[li]
                if lane is None:
                    continue
                s, row = lane
                if s.cancelled:
                    # cancelled mid-step: reclaim the lane, discard the
                    # result (no feedback — the query is already resolved
                    # as timed out / cancelled by the service)
                    s.live_rows -= 1
                    slot.lanes[li] = None
                    slot.dirty.add(li)
                    continue
                keep = s.retire(row, lo_l[li], hi_l[li], ne_l[li],
                                packed[li],
                                self._warm_row(res, packed, li))
                if not keep:
                    slot.lanes[li] = None
                    slot.dirty.add(li)

        # prime every slot, then cycle the ring: retire+reassemble+
        # redispatch one slot while the other D-1 slots' steps execute on
        # device — host pruning bookkeeping overlaps device compute, and
        # D-1 steps are always in flight before we block on scalars.
        # Idle slots reassemble too (a live queue may have admitted new
        # queries since their last dispatch), and the ring only stops
        # once nothing is in flight and the final admit poll is empty.
        slots = [self._new_slot() for _ in range(self.depth)]
        for slot in slots:
            assemble(slot)
            dispatch(slot)
        cur = 0
        while True:
            if all(s.inflight is None for s in slots):
                refill()
                if not claimable:
                    break
            slot = slots[cur]
            if slot.inflight is not None:
                retire(slot)
            assemble(slot)
            dispatch(slot)
            cur = (cur + 1) % self.depth

        if pool_stats.device_steps:
            pool_stats.occupancy = occupied_total / pool_stats.device_steps
        self._finish_pool(pool_stats)
