"""Shared benchmark fixtures: graphs shaped like the paper's datasets
(CPU-scaled), valid-query selection, timing helpers, CSV emission."""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.core import TCQEngine
from repro.graphs import powerlaw_temporal

RESULTS = os.path.join(os.path.dirname(__file__), "results")

# REPRO_BENCH_SMOKE=1 shrinks every graph so the cross-engine divergence
# gates (bench_pipeline / bench_service / bench_streaming) run in CI
# minutes; smoke numbers are never folded into BENCH_wave.json (run.py
# skips the trajectory write).
SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
_S = 8 if SMOKE else 1

# CPU-scaled analogues of the paper's Table 2 graphs (same shape family:
# skewed degrees + bursty timestamps; |V|,|E| scaled to interactive CPU runs)
GRAPHS = {
    "collegemsg": dict(num_vertices=1_800 // _S, num_edges=20_000 // _S,
                       time_span=16_384 // _S, burst_periods=10, seed=42),
    "email": dict(num_vertices=900 // _S, num_edges=12_000 // _S,
                  time_span=8_192 // _S, burst_periods=8, seed=7),
    "mathoverflow": dict(num_vertices=8_000 // _S, num_edges=60_000 // _S,
                         time_span=32_768 // _S, burst_periods=14, seed=11),
}
GRAPH_K = {"collegemsg": 2, "email": 3, "mathoverflow": 2}

_cache: Dict[str, object] = {}


def graph(name: str):
    if name not in _cache:
        _cache[name] = powerlaw_temporal(**GRAPHS[name])
    return _cache[name]


def engine(name: str) -> TCQEngine:
    key = "eng_" + name
    if key not in _cache:
        _cache[key] = TCQEngine(graph(name))
    return _cache[key]


def pick_queries(name: str, n: int, span_uts: int = 90, seed: int = 0,
                 k: int = None, max_results: int = 60) -> List[dict]:
    """Random VALID query windows, result-bounded like the paper's Table 3
    (their 20 selected queries return 2..61 distinct cores).  If the base k
    yields only high-output windows, k is bumped (+1, +2) — same spirit as
    the paper's manual selection of 'moderate' queries."""
    g = graph(name)
    k0 = k or GRAPH_K[name]
    eng = engine(name)
    uts = g.unique_ts
    for k in (k0, k0 + 1, k0 + 2):
        rng = np.random.default_rng(seed)
        out = []
        tries = 0
        while len(out) < n and tries < 60:
            tries += 1
            i = int(rng.integers(0, max(1, uts.size - span_uts - 1)))
            ts, te = int(uts[i]), int(uts[min(i + span_uts, uts.size - 1)])
            res = eng.query(k, ts, te)
            if 1 <= len(res) <= max_results:
                out.append({"graph": name, "k": k, "ts": ts, "te": te,
                            "results": len(res)})
        if len(out) >= n:
            return out
    return out


def assert_cores_equal(got, want, ctx: str = "") -> None:
    """Raise RuntimeError unless two TCQResults hold identical core sets
    (TTI keys, vertex sets, edge counts) — the cross-engine regression
    gate shared by bench_pipeline and bench_service."""
    bg, bw = got.by_tti(), want.by_tti()
    if bg.keys() != bw.keys():
        raise RuntimeError(
            f"result divergence {ctx}: {len(bg)} vs {len(bw)} cores")
    for key, cw in bw.items():
        cg = bg[key]
        if (not np.array_equal(cg.vertices, cw.vertices)
                or cg.n_edges != cw.n_edges):
            raise RuntimeError(f"result divergence {ctx} at core {key}")


def timeit(fn, repeat: int = 1) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, rows: List[dict]) -> None:
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, name + ".json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)
