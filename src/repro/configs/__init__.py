"""Config registry: ``--arch <id>`` for the 10 assigned architectures, the
paper's own TCQ-engine workloads, and reduced smoke variants."""

from __future__ import annotations

import importlib
from typing import Dict, List

_ARCH_MODULES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "granite-34b": "granite_34b",
    "gemma-7b": "gemma_7b",
    "qwen2-7b": "qwen2_7b",
    "gemma2-2b": "gemma2_2b",
    "whisper-small": "whisper_small",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(name: str):
    """Full-size ModelConfig for an architecture id."""
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def get_smoke_config(name: str):
    return get_config(name).smoke()


def get_tcq_config(name: str):
    from repro.configs import tcq

    return tcq.CONFIGS[name]


def list_tcq_configs() -> List[str]:
    from repro.configs import tcq

    return list(tcq.CONFIGS)
