"""Durable streaming: write-ahead journal + point-in-time crash recovery.

``wal_gate``-marked tests are the durability gate: a service killed at
*any* journal point — after each record, mid-record (torn tail), under
bit rot, with its newest snapshot corrupted — must recover to a drain
bit-identical to the uninterrupted run over the surviving journal
prefix, and damaged tails must be *detected and cut*, never silently
replayed.  CI runs them with ``REPRO_WAL_GATE=1`` for the widened
kill-point sweep (every record); they also run (sampled) in plain
tier-1."""

import io
import os
import shutil

import numpy as np
import pytest

from repro.core import (SnapshotCorruption, TCQService, WALError,
                        WALReplayError, WriteAheadLog)
from repro.core import wal as walmod
from repro.core.faultinject import (CrashingWAL, InjectedCrash,
                                    corrupt_snapshot, flip_tail_byte,
                                    torn_tail)
from repro.graphs import powerlaw_temporal

_GATE = os.environ.get("REPRO_WAL_GATE") == "1"


# ------------------------------------------------------------ primitives
def test_record_roundtrip():
    arrays = {"u": np.arange(5, dtype=np.int64),
              "w": np.linspace(0, 1, 3, dtype=np.float32)}
    payload = walmod.encode_record("edges", {"epoch": 3}, arrays)
    # encode_record frames the record: strip the length+crc header
    body = payload[walmod._REC_HEADER.size:]
    rec = walmod.decode_payload(body)
    assert rec.kind == "edges" and rec.meta == {"epoch": 3}
    assert set(rec.arrays) == {"u", "w"}
    for k in arrays:
        np.testing.assert_array_equal(rec.arrays[k], arrays[k])
        assert rec.arrays[k].dtype == arrays[k].dtype


def test_segment_append_read_rotate_gc(tmp_path):
    d = str(tmp_path)
    wal = WriteAheadLog(d, fsync="always")
    for i in range(4):
        assert wal.append("tick", {"i": i}) == i
    seq0 = wal.active_seq
    seq1 = wal.rotate()
    assert seq1 == seq0 + 1
    wal.append("tock", {"i": 99})
    wal.close()
    segs = walmod.list_segments(d)
    assert [s for s, _ in segs] == [seq0, seq1]
    recs, bad, _ = walmod.read_segment(segs[0][1])
    assert bad is None and [r.meta["i"] for r in recs] == [0, 1, 2, 3]
    # replay from a fresh log sees both sealed segments, in order
    wal2 = WriteAheadLog(d, fsync="off")
    assert [r.meta["i"] for r in wal2.replay(seq0)] == [0, 1, 2, 3, 99]
    (tmp_path / "junk.tmp").write_bytes(b"x")
    removed = wal2.gc(seq1)
    assert any(p.endswith("junk.tmp") for p in removed)
    assert [s for s, _ in walmod.list_segments(d)] == [seq1,
                                                      wal2.active_seq]
    wal2.close()


@pytest.mark.parametrize("damage,reason", [("torn", "torn"),
                                           ("flip", "corrupt")])
def test_tail_damage_detected_and_cut(tmp_path, damage, reason):
    d = str(tmp_path)
    wal = WriteAheadLog(d, fsync="always")
    for i in range(3):
        wal.append("tick", {"i": i},
                   {"a": np.arange(64, dtype=np.int64)})
    wal.close()
    (torn_tail if damage == "torn" else flip_tail_byte)(d)
    path = walmod.list_segments(d)[-1][1]
    recs, bad, valid = walmod.read_segment(path)
    assert bad is not None and bad["reason"] == reason
    assert [r.meta["i"] for r in recs] == [0, 1]
    walmod.cut_segment(path, valid)
    assert os.path.getsize(path) == valid
    recs2, bad2, _ = walmod.read_segment(path)     # the cut is clean
    assert bad2 is None and len(recs2) == 2


def test_atomic_snapshot_checksum(tmp_path):
    path = str(tmp_path / "snapshot-00000007.npz")
    meta = {"version": 1, "epoch": 2}
    arrays = {"x": np.arange(100, dtype=np.int32)}
    walmod.write_snapshot_atomic(path, meta, arrays)
    assert not [p for p in os.listdir(str(tmp_path))
                if p.endswith(".tmp")]
    got_meta, got_arrays = walmod.read_snapshot(path)
    assert got_meta["epoch"] == 2 and "checksum" in got_meta
    np.testing.assert_array_equal(got_arrays["x"], arrays["x"])
    with open(path, "r+b") as f:                   # one flipped byte
        f.seek(os.path.getsize(path) // 2)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(SnapshotCorruption):
        walmod.read_snapshot(path)


# --------------------------------------------------- service-level drill
def _graph():
    return powerlaw_temporal(60, 360, 48, seed=5)


def _ops(g, seed=0):
    """Deterministic tape: admissions, a same-tick submit+cancel twin of
    the first request (epoch 0, pre-ingest), ingest, a checkpoint."""
    uts = g.unique_ts
    n = int(uts.size)
    reqs = [{"k": 2 + (i % 2), "ts": int(uts[a]), "te": int(uts[b])}
            for i, (a, b) in enumerate([(0, n // 2), (n // 3, n - 1),
                                        (n // 5, n // 2 + 2),
                                        (1, n // 4)])]
    rng = np.random.default_rng(seed)
    V = int(g.num_vertices)

    def batch(m):
        u = rng.integers(0, V, size=m)
        v = (u + 1 + rng.integers(0, V - 1, size=m)) % V
        t = rng.integers(int(uts[0]), int(uts[-1]) + 1, size=m)
        return (u.astype(np.int64), v.astype(np.int64),
                t.astype(np.int64))

    return ([("submit", reqs[0]), ("submit_cancel", reqs[0])]
            + [("submit", r) for r in reqs[1:3]]
            + [("edges", batch(16)), ("checkpoint",),
               ("submit", reqs[3]), ("edges", batch(8))])


def _drive(svc, ops, tickets=None):
    tickets = {} if tickets is None else tickets
    state = {"i": 0}

    def poll(s):
        if state["i"] >= len(ops):
            return
        op = ops[state["i"]]
        state["i"] += 1
        if op[0] == "submit":
            tk = s.submit(dict(op[1]))
            tickets[tk.id] = tk
        elif op[0] == "submit_cancel":
            tk = s.submit(dict(op[1]))
            tickets[tk.id] = tk
            s.cancel(tk)
        elif op[0] == "edges":
            s.push_edges(*op[1])
        elif op[0] == "checkpoint" and s.wal is not None:
            s.checkpoint()

    while state["i"] < len(ops) or svc.pending:
        svc.run_until_idle(poll)
    return tickets


def _digest(tk):
    return sorted((k, tuple(c.vertices.tolist()), int(c.n_edges))
                  for k, c in tk.result.by_tti().items())


def _roster(d):
    out = []
    for _, path in walmod.list_segments(d):
        recs, bad, _ = walmod.read_segment(path)
        assert bad is None, (path, bad)
        out.extend(recs)
    return out


def _svc(g, **kw):
    return TCQService(g, use_kernel=False, **kw)


def _check_prefix(rec_svc, prefix, precrash, ref, ref_twin):
    """Recovery over one surviving prefix: every journaled admission is
    accounted for and bit-identical to the fault-free reference."""
    got = {tk.id: tk for tk in rec_svc.run_until_idle()}
    cancelled = {int(r.meta["id"]) for r in prefix if r.kind == "cancel"}
    for r in prefix:
        if r.kind != "submit":
            continue
        rid = int(r.meta["id"])
        tk = got.get(rid) or precrash.get(rid)
        assert tk is not None and tk.done, f"admission #{rid} lost"
        if rid in cancelled:
            assert tk.status == "cancelled", (rid, tk.status)
            continue
        want = ref[rid]
        if want.status == "cancelled":     # cancel fell off the tail
            want = ref_twin[(tk.k, tk.h, tk.ts, tk.te, tk.epoch)]
        assert _digest(tk) == _digest(want), rid
    return got


@pytest.fixture(scope="module")
def drill():
    """Shared fixture: graph, tape, fault-free reference, and one
    completed journaled run (the mutilation target + kill roster)."""
    g = _graph()
    ops = _ops(g)
    ref = _drive(_svc(g), ops)
    ref_twin = {(tk.k, tk.h, tk.ts, tk.te, tk.epoch): tk
                for tk in ref.values() if tk.status == "done"}
    import tempfile
    full_dir = tempfile.mkdtemp(prefix="tcq-walgate-")
    svc = _svc(g, wal_dir=full_dir, fsync="always")
    full = _drive(svc, ops)
    svc.wal.close()
    for rid in full:
        if full[rid].status == "done":
            assert _digest(full[rid]) == _digest(ref[rid])
    roster = _roster(full_dir)
    yield dict(g=g, ops=ops, ref=ref, ref_twin=ref_twin,
               full_dir=full_dir, full=full, roster=roster)
    shutil.rmtree(full_dir, ignore_errors=True)


@pytest.mark.wal_gate
def test_kill_after_every_record(drill, tmp_path):
    """The kill-anywhere sweep: die right after record n lands, for
    every n (REPRO_WAL_GATE=1) or a boundary sample (tier-1); recovery
    + drain must be bit-identical over the n+1-record prefix — graph
    fingerprint included."""
    g, ops, roster = drill["g"], drill["ops"], drill["roster"]
    R = len(roster)
    fps, gg = [], g
    for rec in roster:
        if rec.kind == "edges":
            gg = gg.add_edges(rec.arrays["u"], rec.arrays["v"],
                              rec.arrays["t"])
        fps.append(gg.fingerprint())
    sig = [(r.kind, (r.meta or {}).get("id")) for r in roster]
    e0 = next(i for i, r in enumerate(roster) if r.kind == "edges")
    points = range(R) if _GATE else sorted({0, 1, e0, e0 + 1, R - 1})
    for n in points:
        d = str(tmp_path / f"kill{n}")
        killer = CrashingWAL(WriteAheadLog(d, fsync="always"),
                             crash_after_records=n)
        seen = {}
        with pytest.raises(InjectedCrash):
            _drive(_svc(g, wal=killer), ops, seen)
        prefix = _roster(d)
        assert [(r.kind, (r.meta or {}).get("id"))
                for r in prefix] == sig[:n + 1]
        rec_svc = TCQService.recover(d, use_kernel=False)
        _check_prefix(rec_svc, prefix, seen, drill["ref"],
                      drill["ref_twin"])
        assert rec_svc.graph.fingerprint() == fps[n], n
        assert rec_svc.recovery_report["wal_records"] >= 0
        rec_svc.wal.close()


@pytest.mark.wal_gate
@pytest.mark.parametrize("damage,reason", [(torn_tail, "torn"),
                                           (flip_tail_byte, "corrupt")])
def test_recover_from_damaged_tail(drill, tmp_path, damage, reason):
    """A torn or bit-rotted tail record is detected (CRC), reported,
    and physically cut — the drain over the shortened prefix stays
    bit-identical (the damaged record was never acknowledged)."""
    d = str(tmp_path / reason)
    shutil.copytree(drill["full_dir"], d)
    damage(d)
    rec_svc = TCQService.recover(d, use_kernel=False)
    rep = rec_svc.recovery_report
    assert [e["reason"] for e in rep["tail_events"]] == [reason]
    _check_prefix(rec_svc, drill["roster"][:-1], drill["full"],
                  drill["ref"], drill["ref_twin"])
    rec_svc.wal.close()


@pytest.mark.wal_gate
def test_corrupt_newest_snapshot_falls_back(drill, tmp_path):
    """A corrupted newest snapshot is skipped; recovery restores the
    previous retained checkpoint and replays its longer tail — nothing
    is lost, nothing diverges."""
    d = str(tmp_path / "snapfall")
    shutil.copytree(drill["full_dir"], d)
    corrupt_snapshot(d)
    rec_svc = TCQService.recover(d, use_kernel=False)
    rep = rec_svc.recovery_report
    assert len(rep["snapshots_skipped"]) == 1
    _check_prefix(rec_svc, drill["roster"], drill["full"],
                  drill["ref"], drill["ref_twin"])
    rec_svc.wal.close()


def test_recover_mid_checkpoint_crash(drill, tmp_path):
    """Die between the checkpoint's segment rotation and its snapshot
    write (the worst ordering), with a stray half-written ``.tmp``
    strewn in: recovery uses the previous snapshot + one more segment,
    and the next checkpoint's GC sweeps the junk."""
    g, ops = drill["g"], drill["ops"]
    d = str(tmp_path / "rotcrash")
    killer = CrashingWAL(WriteAheadLog(d, fsync="always"),
                         crash_on_rotate=True)
    seen = {}
    with pytest.raises(InjectedCrash):
        _drive(_svc(g, wal=killer), ops, seen)
    junk = os.path.join(d, "snapshot-99999999.npz.tmp")
    with open(junk, "wb") as f:
        f.write(b"half a snapshot")
    prefix = _roster(d)
    rec_svc = TCQService.recover(d, use_kernel=False)
    _check_prefix(rec_svc, prefix, seen, drill["ref"],
                  drill["ref_twin"])
    rec_svc.checkpoint()
    assert not os.path.exists(junk)
    rec_svc.wal.close()


def test_replay_verifies_lineage_and_ids(drill, tmp_path):
    """Replay is checked, not trusted: a journal whose records no longer
    match what the service reproduces (wrong fingerprint, unknown kind)
    raises WALReplayError instead of recovering silently wrong."""
    d = str(tmp_path / "tamper")
    shutil.copytree(drill["full_dir"], d)
    # append a record whose lineage can't hold: an "edges" batch with a
    # deliberately wrong fingerprint
    wal = WriteAheadLog(d, fsync="always")
    wal.append("edges", {"graph_epoch": 999, "num_edges": 1,
                         "num_pairs": 1, "num_vertices": 1,
                         "fingerprint": 12345},
               {"u": np.array([1]), "v": np.array([2]),
                "t": np.array([3])})
    wal.rotate()            # seal it so recovery replays it
    wal.close()
    with pytest.raises(WALReplayError):
        TCQService.recover(d, use_kernel=False)


def test_recover_empty_dir_raises(tmp_path):
    with pytest.raises(WALError):
        TCQService.recover(str(tmp_path / "nothing-here"))


def test_journal_off_by_default():
    g = _graph()
    svc = _svc(g)
    assert svc.wal is None
    svc.submit({"k": 2, "ts": int(g.unique_ts[0]),
                "te": int(g.unique_ts[-1])})
    svc.run_until_idle()
    assert "wal" not in svc.stats


def test_snapshot_includes_live_pool(drill):
    """A snapshot taken from a mid-pool hook still covers the running
    pool's unresolved members — the fix that makes checkpoint() safe
    anywhere on the tape."""
    g = drill["g"]
    svc = _svc(g)
    uts = g.unique_ts
    for i in range(3):
        svc.submit({"k": 2, "ts": int(uts[0]),
                    "te": int(uts[-1 - i])})
    snaps = []

    def poll(s):
        if not snaps and s._inflight:
            snaps.append(s.snapshot())
    svc.run_until_idle(poll)
    assert snaps, "poll never saw a live pool"
    ids = {t["id"] for t in snaps[0]["tickets"]}
    assert ids, "mid-pool snapshot dropped the running tickets"
    restored = TCQService.restore(snaps[0], use_kernel=False)
    got = {tk.id: tk for tk in restored.run_until_idle()}
    assert set(got) == ids
    by_id = {tk.id: tk for tk in svc.completed}
    for rid in ids:
        assert _digest(got[rid]) == _digest(by_id[rid])
