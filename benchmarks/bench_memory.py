"""Paper Table 5: memory — ArrayTEL bytes (device-resident working set),
peel-state bytes, and the PHC-index footprint it replaces."""

from __future__ import annotations

import numpy as np

from repro.core import PHCIndex
from repro.graphs import powerlaw_temporal

from benchmarks.common import GRAPH_K, emit, graph, pick_queries

SCALES = {
    "collegemsg": None,  # use the shared fixture
    "email": None,
    "mathoverflow": None,
    "youtube-mini": dict(num_vertices=60_000, num_edges=400_000,
                         time_span=131_072, burst_periods=16, seed=21),
}


def run():
    rows = []
    for name, spec in SCALES.items():
        g = graph(name) if spec is None else powerlaw_temporal(**spec)
        tel_bytes = g.memory_bytes()
        peel_state = g.num_vertices  # 1 bool per vertex per in-flight cell
        row = {
            "graph": name, "V": g.num_vertices, "E": g.num_edges,
            "P": g.num_pairs, "tel_bytes": tel_bytes,
            "tel_bytes_per_edge": tel_bytes / max(1, g.num_edges),
            "peel_state_bytes_per_lane": peel_state,
        }
        if name in GRAPH_K and g.num_edges <= 30_000:
            q = pick_queries(name, 1, span_uts=60)[0]
            idx = PHCIndex(g, GRAPH_K[name], q["ts"], q["te"])
            row["phc_index_bytes_window"] = idx.nbytes()
            row["phc_index_vs_tel"] = idx.nbytes() / tel_bytes
        rows.append(row)
    emit("bench_memory", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
