"""TCQ-engine workload configs — the paper's system as dry-run peers.

Shapes mirror the paper's Table 2 datasets (vertices/edges/span); the wave
width Q is the batched-engine lever.  These drive the distributed TCQ
dry-run (edges sharded on `model`, query lanes on `data`×`pod`) and the
engine's roofline rows in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TCQConfig:
    name: str
    num_vertices: int
    num_edges: int
    num_pairs: int          # distinct (u,v) links (<= num_edges)
    time_span: int
    wave: int               # query cells peeled per device step
    k: int = 10
    max_peel_iters: int = 32
    notes: str = ""


CONFIGS = {
    # paper Table 2 shape classes
    "tcq-collegemsg": TCQConfig(
        "tcq-collegemsg", num_vertices=2_048, num_edges=20_480,
        num_pairs=16_384, time_span=16_384, wave=256, k=2),
    "tcq-mathoverflow": TCQConfig(
        "tcq-mathoverflow", num_vertices=24_576, num_edges=507_904,
        num_pairs=262_144, time_span=65_536, wave=256, k=2),
    "tcq-youtube": TCQConfig(
        "tcq-youtube", num_vertices=3_276_800, num_edges=9_437_184,
        num_pairs=8_388_608, time_span=1_048_576, wave=64, k=10),
    "tcq-stackoverflow": TCQConfig(
        "tcq-stackoverflow", num_vertices=2_621_440, num_edges=66_060_288,
        num_pairs=50_331_648, time_span=1_048_576, wave=64, k=2),
    # the "billion-edge TEL needs a distributed cluster" case from §7.2
    "tcq-billion": TCQConfig(
        "tcq-billion", num_vertices=134_217_728, num_edges=1_073_741_824,
        num_pairs=805_306_368, time_span=4_194_304, wave=32, k=10,
        notes="hypothetical billion-edge graph: the paper's motivation for a "
              "distributed memory cluster"),
}
