"""Edge-list IO: SNAP/KONECT-style whitespace ``u v t`` files (+ npz cache)."""

from __future__ import annotations

import os

import numpy as np

from repro.core.graph import TemporalGraph


def load_snap_edges(path: str, num_vertices=None,
                    time_unit: int = 1) -> TemporalGraph:
    """Load a SNAP temporal edge list (``SRC DST UNIXTS`` per line).

    time_unit > 1 coarsens timestamps (the paper unifies to seconds; coarser
    units shrink the schedule for interactive experimentation).
    """
    if path.endswith(".npz"):
        z = np.load(path)
        u, v, t = z["u"], z["v"], z["t"]
    else:
        data = np.loadtxt(path, dtype=np.int64, comments=("#", "%"))
        u, v, t = data[:, 0], data[:, 1], data[:, 2]
    if time_unit > 1:
        t = t // time_unit
    t = t - t.min() + 1
    return TemporalGraph.from_edges(u, v, t, num_vertices)


def save_edges(graph: TemporalGraph, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez_compressed(path, u=graph.src, v=graph.dst, t=graph.t)
