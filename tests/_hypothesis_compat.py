"""Seeded fallback for ``hypothesis`` (vendored, minimal).

The property suites import ``given``/``settings``/``strategies`` from here
via a guarded import: when the real hypothesis package is installed it is
used unchanged; in hermetic environments without it this shim keeps the
suites runnable instead of erroring at collection.

Only the API surface the tests use is implemented — ``integers``,
``tuples``, ``lists``, ``composite`` strategies plus the ``@given`` /
``@settings`` decorators.  Examples are drawn from a numpy Generator
seeded by the test name (crc32), so runs are reproducible and failures
can be replayed.  There is no shrinking and no example database.

Example counts are capped (``REPRO_FALLBACK_MAX_EXAMPLES``, default 8):
every distinct random graph shape recompiles the jitted TCD program on
CPU, so the full hypothesis budgets would dominate suite wall time.
"""

from __future__ import annotations

import os
import zlib

import numpy as np


class SearchStrategy:
    """A strategy is just a draw function over a numpy Generator."""

    def __init__(self, draw_fn):
        self._draw = draw_fn

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:
    """Namespace mirror of ``hypothesis.strategies`` (subset)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def tuples(*elements: SearchStrategy) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: tuple(s.draw(rng) for s in elements))

    @staticmethod
    def lists(elements: SearchStrategy, min_size: int = 0,
              max_size: int = None) -> SearchStrategy:
        hi = min_size + 10 if max_size is None else max_size

        def draw(rng):
            n = int(rng.integers(min_size, hi + 1))
            return [elements.draw(rng) for _ in range(n)]

        return SearchStrategy(draw)

    @staticmethod
    def composite(fn):
        def build(*args, **kwargs):
            return SearchStrategy(
                lambda rng: fn(lambda s: s.draw(rng), *args, **kwargs))

        return build


st = strategies


def settings(max_examples: int = 10, deadline=None, **_ignored):
    """Records the example budget on the (already ``@given``-wrapped) test."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strats: SearchStrategy):
    cap = int(os.environ.get("REPRO_FALLBACK_MAX_EXAMPLES", "8"))

    def deco(fn):
        # NOT functools.wraps: pytest would follow __wrapped__ to the
        # original signature and treat the drawn parameters as fixtures
        def wrapper():
            n = min(getattr(wrapper, "_fallback_max_examples", 10), cap)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(max(1, n)):
                fn(*(s.draw(rng) for s in strats))

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
