"""Model assembly: parameter templates, sharding specs, and the forward pass.

Every architecture in the zoo flows through one code path:

  * ``param_template`` declares each parameter's shape + logical axes; from it
    derive real inits (smoke tests), ShapeDtypeStructs (dry-run), and
    PartitionSpecs (FSDP + TP/EP/SP sharding rules with divisibility checks).
  * layers are grouped by the smallest repeating pattern period and parameters
    are stacked over groups; the forward pass ``lax.scan``s over groups with
    ``jax.checkpoint`` (remat) in training — the lowered HLO stays small even
    for the 398B Jamba config.
  * caches (attention KV, Mamba ssm+conv, RWKV wkv+shifts) are pytrees stacked
    the same way and threaded through the scan as xs/ys.

Modes: "train" (full causal, loss-ready hidden states), "prefill" (returns a
filled cache), "decode" (single token against a cache).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import attention
from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import norm, softcap
from repro.models.mlp import mlp, rwkv_channel_mix
from repro.models.moe import moe_ffn
from repro.models.rwkv import rwkv_time_mix
from repro.models.ssm import mamba_mix


class P(NamedTuple):
    """Parameter leaf spec: shape, logical axes (one per dim), init kind."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"


# logical axis -> mesh axis (None = replicate).  "embed" rows are the FSDP
# dimension; "model-ish" axes are tensor/expert parallel.
SHARDING_RULES: Dict[str, Optional[str]] = {
    "embed": "data",
    "vocab": "model",
    "qdim": "model",
    # KV projections stay replicated across TP: GQA ratios (kv=1..8) rarely
    # divide the model axis, and sharding flattened kv*head_dim would split
    # head_dim itself.  They are tiny and still FSDP-sharded on "embed".
    "kvdim": None,
    "heads": "model",
    "ff": "model",
    "eff": None,
    "experts": "model",
    "mamba": "model",
    "mamba2x": "model",
    "seq": None,
    "batch": "data",
    "cache_seq": "model",
    None: None,
}


# --------------------------------------------------------------------- specs
def _norm_t(cfg, name="scale") -> Dict[str, P]:
    t = {"scale": P((cfg.d_model,), (None,), "zeros")}
    if cfg.norm == "layernorm":
        t["scale"] = P((cfg.d_model,), (None,), "ones")
        t["bias"] = P((cfg.d_model,), (None,), "zeros")
    return t


def _attn_t(cfg, cross=False) -> Dict[str, P]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    t = {
        "wq": P((d, h * hd), ("embed", "qdim")),
        "wk": P((d, kv * hd), ("embed", "kvdim")),
        "wv": P((d, kv * hd), ("embed", "kvdim")),
        "wo": P((h * hd, d), ("qdim", "embed")),
    }
    if cfg.qkv_bias and not cross:
        t["bq"] = P((h * hd,), ("qdim",), "zeros")
        t["bk"] = P((kv * hd,), ("kvdim",), "zeros")
        t["bv"] = P((kv * hd,), ("kvdim",), "zeros")
    return t


def _mlp_t(cfg) -> Dict[str, P]:
    d, f = cfg.d_model, cfg.d_ff
    t = {"wu": P((d, f), ("embed", "ff")),
         "wd": P((f, d), ("ff", "embed"))}
    if cfg.glu:
        t["wg"] = P((d, f), ("embed", "ff"))
    return t


def _moe_t(cfg) -> Dict[str, P]:
    m = cfg.moe
    d, fe = cfg.d_model, m.d_expert
    e = m.num_experts
    experts = {"wu": P((e, d, fe), ("experts", "embed", "eff")),
               "wd": P((e, fe, d), ("experts", "eff", "embed"))}
    if cfg.glu:
        experts["wg"] = P((e, d, fe), ("experts", "embed", "eff"))
    t: Dict[str, Any] = {"router": P((d, e), (None, None)),
                         "experts": experts}
    if m.shared_expert:
        t["shared"] = _mlp_t(cfg)
    return t


def _mamba_t(cfg) -> Dict[str, P]:
    m = cfg.mamba
    d = cfg.d_model
    di = m.d_inner(d)
    ds = m.d_state
    dtr = max(1, di // 16)
    return {
        "in_proj": P((d, 2 * di), ("embed", "mamba2x")),
        "conv_w": P((m.d_conv, di), (None, "mamba")),
        "conv_b": P((di,), ("mamba",), "zeros"),
        "x_dbc": P((di, dtr + 2 * ds), ("mamba", None)),
        "dt_proj": P((dtr, di), (None, "mamba")),
        "dt_bias": P((di,), ("mamba",), "dtbias"),
        "A_log": P((di, ds), ("mamba", None), "alog"),
        "D": P((di,), ("mamba",), "ones"),
        "out_proj": P((di, d), ("mamba", "embed")),
    }


def _rwkv_t(cfg) -> Dict[str, P]:
    r = cfg.rwkv
    d = cfg.d_model
    h = d // r.head_dim
    return {
        "mu_x": P((d,), (None,), "zeros"),
        "mu": P((5, d), (None, None), "zeros"),
        "mix_a": P((d, 5 * r.mix_lora), ("embed", None), "small"),
        "mix_b": P((5, r.mix_lora, d), (None, None, "qdim"), "small"),
        "wr": P((d, d), ("embed", "qdim")),
        "wk": P((d, d), ("embed", "qdim")),
        "wv": P((d, d), ("embed", "qdim")),
        "wg": P((d, d), ("embed", "qdim")),
        "wo": P((d, d), ("qdim", "embed")),
        "w0": P((d,), ("qdim",), "zeros"),
        "dec_a": P((d, r.decay_lora), ("embed", None), "small"),
        "dec_b": P((r.decay_lora, d), (None, "qdim"), "small"),
        "u": P((h, r.head_dim), ("heads", None), "small"),
        "ln_x": P((d,), ("qdim",), "ones"),
    }


def _sublayer_t(cfg, spec: LayerSpec, cross: bool) -> Dict[str, Any]:
    t: Dict[str, Any] = {"ln1": _norm_t(cfg)}
    if spec.mixer == "attn":
        t["mixer"] = _attn_t(cfg)
    elif spec.mixer == "mamba":
        t["mixer"] = _mamba_t(cfg)
    elif spec.mixer == "rwkv":
        t["mixer"] = _rwkv_t(cfg)
    if cross:
        t["xln"] = _norm_t(cfg)
        t["xattn"] = _attn_t(cfg, cross=True)
    t["ln2"] = _norm_t(cfg)
    if spec.mixer == "rwkv":
        d, f = cfg.d_model, cfg.d_ff
        t["mlp"] = {"mu_k": P((d,), (None,), "zeros"),
                    "mu_r": P((d,), (None,), "zeros"),
                    "wu": P((d, f), ("embed", "ff")),
                    "wr": P((d, d), ("embed", "qdim")),
                    "wd": P((f, d), ("ff", "embed"))}
    elif spec.mlp == "moe":
        t["mlp"] = _moe_t(cfg)
    else:
        t["mlp"] = _mlp_t(cfg)
    if cfg.post_norms:
        t["pn1"] = _norm_t(cfg)
        t["pn2"] = _norm_t(cfg)
    return t


def param_template(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    t: Dict[str, Any] = {}
    if cfg.input_mode == "tokens" or cfg.encoder_layers:
        t["embed"] = {"tok": P((cfg.padded_vocab, d), ("vocab", "embed"),
                               "embed")}
    if cfg.pos == "learned":
        t.setdefault("embed", {})["pos"] = P((cfg.max_seq, d),
                                             ("seq", "qdim"), "embed")
    period = cfg.scan_period()
    groups = cfg.n_layers // period
    specs = cfg.layer_specs()[:period]
    dec = {}
    for i, spec in enumerate(specs):
        sub = _sublayer_t(cfg, spec, cross=cfg.encoder_layers > 0)
        dec[f"sub{i}"] = jax.tree.map(
            lambda p: P((groups,) + p.shape, (None,) + p.axes, p.init),
            sub, is_leaf=lambda x: isinstance(x, P))
    t["dec"] = dec
    if cfg.encoder_layers:
        enc_spec = LayerSpec(mixer="attn", mlp="dense")
        sub = _sublayer_t(cfg, enc_spec, cross=False)
        t["enc"] = {"sub0": jax.tree.map(
            lambda p: P((cfg.encoder_layers,) + p.shape, (None,) + p.axes,
                        p.init),
            sub, is_leaf=lambda x: isinstance(x, P))}
        t["enc_norm"] = _norm_t(cfg)
    t["final_norm"] = _norm_t(cfg)
    if not cfg.tie_embeddings:
        t["lm_head"] = P((d, cfg.padded_vocab), ("embed", "vocab"))
    return t


# ----------------------------------------------------------------- realize
def _dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def init_params(cfg: ModelConfig, seed: int = 0):
    tmpl = param_template(cfg)
    leaves, treedef = jax.tree.flatten(
        tmpl, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))

    def make(p: P, key):
        if p.init == "zeros":
            return jnp.zeros(p.shape, _dtype(cfg))
        if p.init == "ones":
            return jnp.ones(p.shape, _dtype(cfg))
        if p.init == "alog":
            ds = p.shape[-1]
            a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32),
                         p.shape[:-1] + (1,)).reshape(p.shape)
            return jnp.log(a).astype(_dtype(cfg))
        if p.init == "dtbias":
            return jnp.full(p.shape, math.log(math.e - 1), _dtype(cfg))
        scale = 0.006 if p.init == "small" else 0.02
        if p.init == "embed":
            scale = 1.0 / math.sqrt(cfg.d_model)
        return (jax.random.normal(key, p.shape, jnp.float32)
                * scale).astype(_dtype(cfg))

    return jax.tree.unflatten(treedef, [make(p, k)
                                        for p, k in zip(leaves, keys)])


def abstract_params(cfg: ModelConfig):
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, _dtype(cfg)),
        param_template(cfg), is_leaf=lambda x: isinstance(x, P))


def param_pspecs(cfg: ModelConfig, mesh):
    from jax.sharding import PartitionSpec

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec(p: P):
        parts = []
        for dim, ax in zip(p.shape, p.axes):
            mesh_ax = SHARDING_RULES.get(ax)
            if mesh_ax is not None and dim % sizes[mesh_ax] == 0 and dim > 1:
                parts.append(mesh_ax)
            else:
                parts.append(None)
        # never map one mesh axis to two tensor dims
        seen = set()
        clean = []
        for a in parts:
            if a is not None and a in seen:
                clean.append(None)
            else:
                clean.append(a)
                seen.add(a)
        return PartitionSpec(*clean)

    return jax.tree.map(spec, param_template(cfg),
                        is_leaf=lambda x: isinstance(x, P))


# -------------------------------------------------------------------- cache
def cache_template(cfg: ModelConfig, batch: int, s_max: int,
                   s_enc: Optional[int] = None) -> Dict[str, Any]:
    """Shape/axes template for decode caches (same P-leaf formalism)."""
    period = cfg.scan_period()
    groups = cfg.n_layers // period
    specs = cfg.layer_specs()[:period]
    hd = cfg.resolved_head_dim
    kv = cfg.n_kv_heads
    d = cfg.d_model
    t: Dict[str, Any] = {}
    for i, spec in enumerate(specs):
        sub: Dict[str, P] = {}
        if spec.mixer == "attn":
            sub["k"] = P((groups, batch, s_max, kv, hd),
                         (None, "batch", "cache_seq", "kvheads", None))
            sub["v"] = P((groups, batch, s_max, kv, hd),
                         (None, "batch", "cache_seq", "kvheads", None))
        elif spec.mixer == "mamba":
            m = cfg.mamba
            di = m.d_inner(d)
            sub["ssm"] = P((groups, batch, di, m.d_state),
                           (None, "batch", "mamba", None))
            sub["conv"] = P((groups, batch, m.d_conv - 1, di),
                            (None, "batch", None, "mamba"))
        elif spec.mixer == "rwkv":
            r = cfg.rwkv
            h = d // r.head_dim
            sub["wkv"] = P((groups, batch, h, r.head_dim, r.head_dim),
                           (None, "batch", "heads", None, None))
            sub["shift_att"] = P((groups, batch, d), (None, "batch", None))
            sub["shift_ffn"] = P((groups, batch, d), (None, "batch", None))
        if cfg.encoder_layers and s_enc:
            sub["xk"] = P((groups, batch, s_enc, kv, hd),
                          (None, "batch", None, "kvheads", None))
            sub["xv"] = P((groups, batch, s_enc, kv, hd),
                          (None, "batch", None, "kvheads", None))
        t[f"sub{i}"] = sub
    return t


def init_cache(cfg, batch, s_max, s_enc=None, abstract=False):
    tmpl = cache_template(cfg, batch, s_max, s_enc)

    def make(p: P):
        if abstract:
            return jax.ShapeDtypeStruct(p.shape, _dtype(cfg))
        return jnp.zeros(p.shape, _dtype(cfg))

    return jax.tree.map(make, tmpl, is_leaf=lambda x: isinstance(x, P))


def cache_pspecs(cfg, mesh, batch, s_max, s_enc=None):
    from jax.sharding import PartitionSpec

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    rules = dict(SHARDING_RULES)
    rules["kvheads"] = "model"

    def spec(p: P):
        parts = []
        seen = set()
        for dim, ax in zip(p.shape, p.axes):
            mesh_ax = rules.get(ax)
            if (mesh_ax is not None and mesh_ax not in seen
                    and dim % sizes[mesh_ax] == 0 and dim > 1):
                parts.append(mesh_ax)
                seen.add(mesh_ax)
            else:
                parts.append(None)
        return PartitionSpec(*parts)

    return jax.tree.map(spec, cache_template(cfg, batch, s_max, s_enc),
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------------------ forward
def _run_sublayer(cfg, spec: LayerSpec, p, x, positions, *, causal, cache,
                  cache_index, enc_out, aux, decode=False):
    new_cache = {}
    h = norm(x, p["ln1"], cfg.norm)
    if spec.mixer == "attn":
        attn_cache = ({"k": cache["k"], "v": cache["v"]}
                      if cache and "k" in cache else None)
        out, nc = attention(p["mixer"], h, cfg, spec, positions,
                            causal=causal, cache=attn_cache,
                            cache_index=cache_index)
        if nc:
            new_cache.update(nc)
    elif spec.mixer == "mamba":
        state = (cache["ssm"], cache["conv"]) if cache else None
        if state is None:
            m = cfg.mamba
            b = x.shape[0]
            state = (jnp.zeros((b, m.d_inner(cfg.d_model), m.d_state),
                               jnp.float32),
                     jnp.zeros((b, m.d_conv - 1, m.d_inner(cfg.d_model)),
                               x.dtype))
        out, (s1, c1) = mamba_mix(p["mixer"], h, cfg, state,
                                  chunk=cfg.mamba_chunk,
                                  scan_impl=cfg.mamba_scan)
        new_cache.update({"ssm": s1.astype(x.dtype), "conv": c1})
    elif spec.mixer == "rwkv":
        r = cfg.rwkv
        b = x.shape[0]
        hds = cfg.d_model // r.head_dim
        state = ((cache["wkv"], cache["shift_att"]) if cache else
                 (jnp.zeros((b, hds, r.head_dim, r.head_dim), jnp.float32),
                  jnp.zeros((b, cfg.d_model), x.dtype)))
        out, (wkv1, sh1) = rwkv_time_mix(p["mixer"], h, cfg, state)
        new_cache.update({"wkv": wkv1.astype(x.dtype), "shift_att": sh1})
    else:
        out = jnp.zeros_like(x)
    if cfg.post_norms:
        out = norm(out, p["pn1"], cfg.norm)
    x = x + out

    if "xattn" in p and (enc_out is not None or decode):
        hx = norm(x, p["xln"], cfg.norm)
        # decode reads the cross KV cached at prefill; prefill computes it
        xc = ({"xk": cache["xk"], "xv": cache["xv"]}
              if decode and cache and "xk" in cache else None)
        out, xnc = attention(p["xattn"], hx, cfg, spec, positions,
                             causal=False, cache=xc,
                             kv_source=None if xc else enc_out)
        if xnc:
            new_cache.update(xnc)
        x = x + out

    h2 = norm(x, p["ln2"], cfg.norm)
    if spec.mixer == "rwkv":
        shift = cache["shift_ffn"] if cache else jnp.zeros(
            (x.shape[0], cfg.d_model), x.dtype)
        out, sh2 = rwkv_channel_mix(p["mlp"], h2, shift, cfg)
        new_cache["shift_ffn"] = sh2
    elif spec.mlp == "moe":
        out, a = moe_ffn(p["mlp"], h2, cfg)
        aux = aux + a
    else:
        out = mlp(p["mlp"], h2, cfg)
    if cfg.post_norms:
        out = norm(out, p["pn2"], cfg.norm)
    return x + out, new_cache, aux


def _ac(x, sharding):
    """Activation sharding constraint (no-op when sharding is None).
    Without this, GSPMD inherits the FSDP `d`-over-data layout from the
    embedding table and replicates the batch through attention."""
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


def _stack_forward(cfg, stack_params, x, positions, *, specs, causal,
                   cache=None, cache_index=None, enc_out=None, remat=True,
                   decode=False, act_sharding=None):
    """Scan over layer groups.  cache (optional) is threaded as xs/ys."""
    period = len(specs)

    def body(carry, xs):
        xh, aux = carry
        gp, gc = xs
        new_gc = {}
        for i, spec in enumerate(specs):
            sub_c = gc.get(f"sub{i}") if gc is not None else None
            xh, nc, aux = _run_sublayer(
                cfg, spec, gp[f"sub{i}"], xh, positions, causal=causal,
                cache=sub_c, cache_index=cache_index, enc_out=enc_out,
                aux=aux, decode=decode)
            xh = _ac(xh, act_sharding)
            if nc:
                new_gc[f"sub{i}"] = nc
        return (xh, aux), new_gc

    fn = jax.checkpoint(body) if remat else body
    (x, aux), new_cache = jax.lax.scan(
        fn, (x, jnp.zeros((), jnp.float32)), (stack_params, cache))
    return x, aux, new_cache


def _embed_in(cfg, params, batch, positions):
    if cfg.input_mode == "embeds" and "embeds" in batch:
        x = batch["embeds"].astype(_dtype(cfg))
    else:
        x = jnp.take(params["embed"]["tok"], batch["tokens"], axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.pos == "learned":
        pos = positions if positions.ndim == 2 else positions[0]
        x = x + jnp.take(params["embed"]["pos"], pos, axis=0)
    return x


def _positions(batch, s, b):
    if "positions" in batch:
        return batch["positions"]
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


def run_encoder(cfg, params, enc_embeds, act_sharding=None):
    b, s, _ = enc_embeds.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _ac(enc_embeds.astype(_dtype(cfg)), act_sharding)
    enc_spec = LayerSpec(mixer="attn", mlp="dense")
    x, _, _ = _stack_forward(cfg, params["enc"], x, pos,
                             specs=[enc_spec], causal=False,
                             act_sharding=act_sharding)
    return norm(x, params["enc_norm"], cfg.norm)


def forward(cfg: ModelConfig, params, batch, *, mode: str = "train",
            cache=None, act_sharding=None):
    """Returns (hidden [B,S,d], aux_loss, new_cache)."""
    specs = cfg.layer_specs()[:cfg.scan_period()]
    enc_out = None
    if cfg.encoder_layers and "enc_embeds" in batch:
        enc_out = run_encoder(cfg, params, batch["enc_embeds"],
                              act_sharding)
    if cfg.input_mode == "embeds" and "embeds" in batch:
        b, s = batch["embeds"].shape[:2]
    else:
        b, s = batch["tokens"].shape
    positions = _positions(batch, s, b)
    x = _ac(_embed_in(cfg, params, batch, positions), act_sharding)
    cache_index = batch.get("cache_index") if cache is not None else None
    if cache is not None and cache_index is None:
        cache_index = jnp.zeros((), jnp.int32)
    x, aux, new_cache = _stack_forward(
        cfg, params["dec"], x, positions, specs=specs,
        causal=True, cache=cache, cache_index=cache_index,
        enc_out=enc_out, remat=(mode == "train"), decode=(mode == "decode"),
        act_sharding=act_sharding)
    x = norm(x, params["final_norm"], cfg.norm)
    return x, aux, new_cache


def logits_from_hidden(cfg, params, hidden):
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].T
    else:
        w = params["lm_head"]
    logits = hidden @ w.astype(hidden.dtype)
    logits = softcap(logits, cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab:  # mask the TP-padding token columns
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return logits


def loss_fn(cfg: ModelConfig, params, batch, act_sharding=None):
    """Causal LM loss (f32 logsumexp), labels < 0 are masked."""
    hidden, aux, _ = forward(cfg, params, batch, mode="train",
                             act_sharding=act_sharding)
    logits = logits_from_hidden(cfg, params, hidden).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom + 0.01 * aux
    return loss, {"nll": nll.sum() / denom, "aux": aux,
                  "tokens": mask.sum()}
