"""Distributed TCQ engine: the paper's system at pod scale via shard_map.

Layout (mesh (pod, data, model) or (data, model)):
  * edges + pairs shard over `model`, split at PAIR boundaries so the
    edge->pair reduction never crosses shards (zero-collective pair stage);
    shards are padded to equal length with never-active sentinel edges.
  * query lanes (the OTCD wave) shard over `pod` x `data` — embarrassingly
    parallel, linear scaling.
  * the only cross-shard exchange is the per-iteration vertex-degree
    combine over `model`.  Two variants (EXPERIMENTS §Perf hillclimbs them):
      combine="psum":  all-reduce of the dense [V, Q_loc] f32 degrees;
      combine="rs_ag": psum_scatter the degrees, threshold locally, then
                       all-gather the 1-bit alive mask — ~36x less wire.

The paper's Table 5 notes billion-edge TELs "would require the distributed
memory cluster"; this module is that cluster design, with the tcq-billion
config lowering on the 512-chip multi-pod mesh.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as PS

from repro.core.graph import TemporalGraph
from repro.launch.mesh import dp_axes

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min


class ShardedTEL(NamedTuple):
    """Host-side pair-aligned edge partition, stacked as [m, ...] arrays."""
    src: np.ndarray        # [m, E_s]
    dst: np.ndarray        # [m, E_s]
    t: np.ndarray          # [m, E_s]  (-1 => sentinel padding)
    pair_local: np.ndarray  # [m, E_s]  local pair id (P_s => sentinel)
    hp_src: np.ndarray     # [m, HP_s] vertex of half-pair (V_pad => sentinel)
    hp_pair: np.ndarray    # [m, HP_s] local pair id
    num_vertices: int      # padded to a multiple of m
    num_pairs_shard: int
    num_shards: int


def shard_graph(graph: TemporalGraph, m: int) -> ShardedTEL:
    e, p = graph.num_edges, graph.num_pairs
    # pair-aligned edge splits: first edge of the pair at each target cut
    pair_first_edge = np.searchsorted(graph.pair_id, np.arange(p))
    cuts = [0]
    for i in range(1, m):
        target = min(i * (-(-e // m)), e)
        pid = graph.pair_id[min(target, e - 1)]
        cuts.append(int(pair_first_edge[pid]))
    cuts.append(e)
    e_s = max(cuts[i + 1] - cuts[i] for i in range(m)) if e else 1
    p_ranges = [(int(graph.pair_id[cuts[i]]) if cuts[i] < e else p,
                 int(graph.pair_id[cuts[i + 1] - 1]) + 1
                 if cuts[i + 1] > cuts[i] else
                 (int(graph.pair_id[cuts[i]]) if cuts[i] < e else p))
                for i in range(m)]
    p_s = max((hi - lo for lo, hi in p_ranges), default=1) or 1
    # vertex shards must byte-align for the bitpacked alive exchange
    v_pad = -(-graph.num_vertices // (8 * m)) * 8 * m

    src = np.zeros((m, e_s), np.int32)
    dst = np.zeros((m, e_s), np.int32)
    tt = np.full((m, e_s), -1, np.int32)
    pl_ = np.full((m, e_s), p_s, np.int32)
    hp_s = 2 * p_s
    hps = np.full((m, hp_s), v_pad, np.int32)
    hpp = np.full((m, hp_s), p_s, np.int32)
    for i in range(m):
        a, b = cuts[i], cuts[i + 1]
        n = b - a
        src[i, :n] = graph.src[a:b]
        dst[i, :n] = graph.dst[a:b]
        tt[i, :n] = graph.t[a:b]
        lo, hi = p_ranges[i]
        pl_[i, :n] = graph.pair_id[a:b] - lo
        np_l = hi - lo
        h_src = np.concatenate([graph.pair_u[lo:hi], graph.pair_v[lo:hi]])
        h_pair = np.concatenate([np.arange(np_l), np.arange(np_l)])
        order = np.argsort(h_src, kind="stable")
        hps[i, :2 * np_l] = h_src[order]
        hpp[i, :2 * np_l] = h_pair[order]
    return ShardedTEL(src, dst, tt, pl_, hps, hpp, v_pad, p_s, m)


def abstract_sharded_tel(num_vertices: int, num_edges: int, num_pairs: int,
                         m: int) -> ShardedTEL:
    """ShapeDtypeStruct stand-in for the dry-run (no allocation)."""
    e_s = -(-num_edges // m)
    p_s = -(-num_pairs // m)
    v_pad = -(-num_vertices // (8 * m)) * 8 * m
    sds = lambda shape: jax.ShapeDtypeStruct(shape, jnp.int32)  # noqa: E731
    tel = ShardedTEL(sds((m, e_s)), sds((m, e_s)), sds((m, e_s)),
                     sds((m, e_s)), sds((m, 2 * p_s)), sds((m, 2 * p_s)),
                     v_pad, p_s, m)
    return tel


def _local_degrees(src, dst, t, pair_l, hp_src, hp_pair, alive, ts, te, h,
                   *, p_s, v_pad):
    """One shard's partial degrees.  alive: [Qloc, V]; returns [V, Qloc]."""
    win = (t[None, :] >= ts[:, None]) & (t[None, :] <= te[:, None])
    ea = win & alive[:, src] & alive[:, dst]                 # [Qloc, E_s]
    paircnt = jax.ops.segment_sum(ea.T.astype(jnp.float32), pair_l,
                                  num_segments=p_s + 1,
                                  indices_are_sorted=True)[:p_s]
    pairact = (paircnt >= h).astype(jnp.float32)             # [P_s, Qloc]
    contrib = pairact[jnp.minimum(hp_pair, p_s - 1), :]
    deg = jax.ops.segment_sum(contrib, hp_src,
                              num_segments=v_pad + 1,
                              indices_are_sorted=True)[:v_pad]
    return deg                                               # [V, Qloc]


def build_wave_step(mesh, *, num_vertices: int, combine: str = "rs_ag",
                    p_s: int, max_iters: int = 0, single_iteration=False):
    """shard_map'd batched peel over (pod, data | data) query lanes and
    model-axis edge shards.  Returns a jit-able
    step(tel_arrays..., alive, ts, te, k, h) -> (alive, tti_lo, tti_hi,
    n_edges, iters)."""
    dp = dp_axes(mesh)
    m = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    v_pad = num_vertices
    assert v_pad % m == 0

    def deg_combine(deg_part, alive):
        if combine == "psum":
            deg = lax.psum(deg_part, "model")                # [V, Qloc]
            return deg.T
        # reduce_scatter over V, threshold locally, all-gather bool alive
        deg_s = lax.psum_scatter(deg_part, "model",
                                 scatter_dimension=0, tiled=True)
        return deg_s.T                                       # [Qloc, V/m]

    def one_iter(src, dst, t, pair_l, hp_src, hp_pair, alive, ts, te, k, h):
        deg_part = _local_degrees(src, dst, t, pair_l, hp_src, hp_pair,
                                  alive, ts, te, h, p_s=p_s, v_pad=v_pad)
        if combine == "psum":
            deg = lax.psum(deg_part, "model").T              # [Qloc, V]
            return alive & (deg >= k)
        deg_s = lax.psum_scatter(deg_part, "model",
                                 scatter_dimension=0, tiled=True).T
        idx = lax.axis_index("model")
        v_m = v_pad // m
        alive_slice = lax.dynamic_slice_in_dim(alive, idx * v_m, v_m, axis=1)
        new_slice = alive_slice & (deg_s >= k)
        if combine == "rs_ag_packed":
            # §Perf iteration 3: gather 1 BIT per vertex instead of one
            # byte — 8x less wire on the alive exchange
            packed = jnp.packbits(new_slice, axis=1)
            gathered = lax.all_gather(packed, "model", axis=1, tiled=True)
            return jnp.unpackbits(
                gathered, axis=1, count=v_pad).astype(bool)
        return lax.all_gather(new_slice, "model", axis=1, tiled=True)

    def step(src, dst, t, pair_l, hp_src, hp_pair, alive, ts, te, k, h):
        src, dst, t = src[0], dst[0], t[0]
        pair_l, hp_src, hp_pair = pair_l[0], hp_src[0], hp_pair[0]
        if single_iteration:
            alive = one_iter(src, dst, t, pair_l, hp_src, hp_pair, alive,
                             ts, te, k, h)
            iters = jnp.int32(1)
        else:
            def cond(s):
                a, changed, it = s
                more = changed
                if max_iters:
                    more = more & (it < max_iters)
                return more

            def body(s):
                a, _, it = s
                na = one_iter(src, dst, t, pair_l, hp_src, hp_pair, a,
                              ts, te, k, h)
                return na, jnp.any(na != a), it + 1

            alive, _, iters = lax.while_loop(
                cond, body, (alive, jnp.bool_(True), jnp.int32(0)))
        # TTI + edge counts: local then min/max/sum over the model axis
        win = (t[None, :] >= ts[:, None]) & (t[None, :] <= te[:, None])
        ea = win & alive[:, src] & alive[:, dst]
        n_edges = lax.psum(jnp.sum(ea, axis=1, dtype=jnp.int32), "model")
        lo = lax.pmin(jnp.min(jnp.where(ea, t[None, :], _I32_MAX), axis=1),
                      "model")
        hi = lax.pmax(jnp.max(jnp.where(ea, t[None, :], _I32_MIN),
                              axis=1), "model")
        return alive, lo, hi, n_edges, iters

    edge_spec = PS("model", None)
    lane_axes = dp if len(dp) > 1 else dp[0]
    lane = PS(lane_axes)
    alive_spec = PS(lane_axes, None)
    from jax.experimental.shard_map import shard_map

    smapped = shard_map(
        step, mesh=mesh,
        in_specs=(edge_spec, edge_spec, edge_spec, edge_spec, edge_spec,
                  edge_spec, alive_spec, lane, lane, PS(), PS()),
        out_specs=(alive_spec, lane, lane, lane, PS()),
        check_rep=False)
    return smapped


def wave_shardings(mesh, num_vertices: int, m: int):
    dp = dp_axes(mesh)
    lane = dp if len(dp) > 1 else dp[0]
    return {
        "edges": NamedSharding(mesh, PS("model", None)),
        "alive": NamedSharding(mesh, PS(lane, None)),
        "lane": NamedSharding(mesh, PS(lane)),
        "scalar": NamedSharding(mesh, PS()),
    }


class DistributedTCQ:
    """Runnable distributed engine (any mesh, incl. degenerate test meshes).

    On a single-device mesh the shard_map program degenerates to the
    plain composite with collective no-ops, so the single-shard block
    routes through ``core.wave.make_wave_step_fn`` instead — the fused
    Pallas peel-to-fixpoint kernel on TPU, the XLA composite elsewhere
    (``use_fused=False`` restores the pure shard_map path, e.g. for the
    collective-lowering dry runs; ``True`` forces the kernel).  Multi-
    device meshes always run the sharded step — the fused kernel owns
    the *intra-shard* work and the model-axis degree combine stays a
    collective.
    """

    def __init__(self, graph: TemporalGraph, mesh, combine: str = "rs_ag",
                 *, use_fused: Optional[bool] = None):
        self.graph = graph
        self.mesh = mesh
        m = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        plan = shard_graph(graph, m)
        self.plan = plan
        sh = wave_shardings(mesh, plan.num_vertices, m)
        self.arrays = tuple(
            jax.device_put(a, sh["edges"])
            for a in (plan.src, plan.dst, plan.t, plan.pair_local,
                      plan.hp_src, plan.hp_pair))
        self.step = jax.jit(build_wave_step(
            mesh, num_vertices=plan.num_vertices, combine=combine,
            p_s=plan.num_pairs_shard))
        self._sh = sh
        self._fused = None
        if mesh.devices.size == 1 and use_fused is not False:
            from repro.core.wave import make_wave_step_fn

            tel = graph.device_tel(vertex_capacity=plan.num_vertices)
            self._fused = make_wave_step_fn(tel, plan.num_vertices,
                                            use_kernel=use_fused)

    def query_wave(self, ts, te, k: int, h: int = 1, alive=None, *,
                   packed: bool = False):
        """Batched peel over the sharded TEL.  With ``packed=True`` the
        alive masks come back as [Q, ceil(V/32)] uint32 bitmasks (the
        engine's packed result-transfer path — 8x less wire than bool
        masks when the caller only needs them host-side; decode with
        ``engine.unpack_alive_u32``)."""
        q = len(ts)
        v = self.plan.num_vertices
        if alive is None:
            alive = jnp.ones((q, v), dtype=bool)
        if self._fused is not None:
            # single-shard block: the fused step already emits the packed
            # bitmask, so the packed transfer costs nothing extra here
            r = self._fused(jnp.asarray(alive, dtype=bool),
                            jnp.asarray(ts, jnp.int32),
                            jnp.asarray(te, jnp.int32),
                            jnp.int32(k), jnp.int32(h))
            if packed:
                return r.packed, r.tti_lo, r.tti_hi, r.n_edges, r.iters
            return r.alive, r.tti_lo, r.tti_hi, r.n_edges, r.iters
        alive = jax.device_put(alive, self._sh["alive"])
        ts = jax.device_put(jnp.asarray(ts, jnp.int32), self._sh["lane"])
        te = jax.device_put(jnp.asarray(te, jnp.int32), self._sh["lane"])
        out = self.step(*self.arrays, alive, ts, te, jnp.int32(k),
                        jnp.int32(h))
        if packed:
            from repro.core.engine import pack_alive_u32

            alive_out, lo, hi, ne, iters = out
            return (pack_alive_u32(alive_out, num_vertices=v),
                    lo, hi, ne, iters)
        return out
