"""Dispatcher for the SSM scan: Pallas on TPU, interpret on CPU tests,
jnp reference otherwise."""

from __future__ import annotations

import jax

from repro.kernels.ssm_scan.kernel import ssm_scan_pallas
from repro.kernels.ssm_scan.ref import ssm_scan_ref


def ssm_scan(log_a, bx, s0, *, use_kernel: bool = True, interpret=None):
    if not use_kernel:
        return ssm_scan_ref(log_a, bx, s0)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ssm_scan_pallas(log_a, bx, s0, interpret=interpret)
