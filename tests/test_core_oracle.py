"""OTCD / TCD / wave engines against the brute-force oracle."""

import numpy as np
import pytest

from repro.core import TCQEngine, TemporalGraph, brute_force_query
from repro.graphs import (erdos_temporal, paper_style_example, planted_cores,
                          powerlaw_temporal)

CASES = [
    ("paper", paper_style_example(), 2, 1, 8, 1),
    ("planted", planted_cores(seed=3), 3, 1, 40, 1),
    ("powerlaw", powerlaw_temporal(80, 500, 60, seed=1), 2, 1, 60, 1),
    ("erdos", erdos_temporal(40, 300, 25, seed=5), 3, 1, 25, 1),
    ("subwindow", planted_cores(seed=9), 3, 10, 30, 1),
    ("strength", erdos_temporal(20, 400, 12, seed=2), 2, 1, 12, 2),
    ("k1", paper_style_example(), 1, 1, 8, 1),
]


def _check(result, oracle):
    assert set(c.tti for c in result.cores) == set(oracle.keys())
    for c in result.cores:
        assert set(c.vertices.tolist()) == set(oracle[c.tti]["vertices"])
        assert c.n_edges == oracle[c.tti]["n_edges"]


@pytest.mark.parametrize("name,g,k,Ts,Te,h", CASES, ids=[c[0] for c in CASES])
@pytest.mark.parametrize("kw", [
    {},                                  # serial OTCD (paper §4)
    {"algorithm": "tcd"},                # unpruned TCD (paper §3)
    {"mode": "wave", "wave": 4},         # batched engine (beyond paper)
], ids=["otcd", "tcd", "wave"])
def test_engine_matches_oracle(name, g, k, Ts, Te, h, kw):
    oracle = brute_force_query(g, k, Ts, Te, h)
    res = TCQEngine(g).query(k, Ts, Te, h=h, **kw)
    _check(res, oracle)


def test_otcd_evaluates_fewer_cells_than_tcd():
    g = planted_cores(seed=3)
    eng = TCQEngine(g)
    a = eng.query(3, 1, 40)
    b = eng.query(3, 1, 40, algorithm="tcd")
    assert a.stats.cells_evaluated < b.stats.cells_evaluated
    assert a.stats.pruned_total > 0


def test_wave_uses_fewer_device_steps():
    g = planted_cores(seed=3)
    eng = TCQEngine(g)
    serial = eng.query(3, 1, 40)
    wave = eng.query(3, 1, 40, mode="wave", wave=16)
    assert wave.stats.device_steps < serial.stats.device_steps


def test_integer_boundaries_add_no_new_cores():
    """Unique-timestamp compaction is exact: enumerating every integer
    (ts, te) boundary pair yields the same distinct-core set."""
    from repro.core.oracle import peel_window

    g = paper_style_example()
    k = 2
    full = {}
    for ts in range(1, 9):
        for te in range(ts, 9):
            em = peel_window(g, ts, te, k)
            if em.any():
                tti = (int(g.t[em].min()), int(g.t[em].max()))
                full.setdefault(tti, int(em.sum()))
    compact = brute_force_query(g, k, 1, 8)
    assert set(full.keys()) == set(compact.keys())


def test_historical_kcore_special_case():
    """HCQ (paper Def. 1) == the TCQ result whose TTI is maximal: querying
    the fixed window returns the same top core as peeling it directly."""
    from repro.core.oracle import peel_window

    g = planted_cores(seed=4)
    em = peel_window(g, 5, 30, 3)
    res = TCQEngine(g).query(3, 5, 30)
    if not em.any():
        assert len(res) == 0
    else:
        verts = set(np.unique(np.concatenate(
            [g.src[em], g.dst[em]])).tolist())
        top = max(res.cores, key=lambda c: c.n_edges)
        assert set(top.vertices.tolist()) == verts


def test_empty_window():
    g = paper_style_example()
    res = TCQEngine(g).query(2, 100, 200)
    assert len(res) == 0


def test_k_too_large():
    g = paper_style_example()
    res = TCQEngine(g).query(50, 1, 8)
    assert len(res) == 0
