"""Optimizers: AdamW and Adafactor (factored second moment).

Both are pytree-native (no optax dependency) and sharding-aware: states
inherit parameter PartitionSpecs (`opt_state_pspecs`), so FSDP shards the
optimizer exactly like the weights (ZeRO-3).  Adafactor is the default for
the 398B-class configs — fp32 Adam moments on 398B params would blow the
16 GB/chip budget (see EXPERIMENTS §Dry-run).  ``state_dtype`` optionally
keeps AdamW moments in bf16 (a further 4x cut, with update error feedback
left to gradient compression).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: Optional[str] = None  # None => follow param dtype

    def _sdtype(self, p):
        return jnp.dtype(self.state_dtype) if self.state_dtype else p.dtype

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, self._sdtype(p))  # noqa: E731
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def init_abstract(self, params):
        z = lambda p: jax.ShapeDtypeStruct(p.shape, self._sdtype(p))  # noqa: E731
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
                "step": jax.ShapeDtypeStruct((), jnp.int32)}

    def update(self, grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m1 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * gf
            v1 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * gf * gf
            u = (m1 / c1) / (jnp.sqrt(v1 / c2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (-self.lr * u).astype(p.dtype), m1.astype(m.dtype), \
                v1.astype(v.dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v, "step": step}

    def state_pspecs(self, param_pspecs):
        from jax.sharding import PartitionSpec

        return {"m": param_pspecs, "v": param_pspecs,
                "step": PartitionSpec()}


@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: float = 1e-3
    decay: float = 0.8       # beta2 = 1 - step^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def _factored(self, shape):
        return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1

    def init(self, params):
        def make(p):
            if self._factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": jax.tree.map(make, params),
                "step": jnp.zeros((), jnp.int32)}

    def init_abstract(self, params):
        def make(p):
            if self._factored(p.shape):
                return {"vr": jax.ShapeDtypeStruct(p.shape[:-1], jnp.float32),
                        "vc": jax.ShapeDtypeStruct(
                            p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jax.ShapeDtypeStruct(p.shape, jnp.float32)}

        return {"f": jax.tree.map(make, params),
                "step": jax.ShapeDtypeStruct((), jnp.int32)}

    def update(self, grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-self.decay)

        def upd(g, f, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + self.eps
            if self._factored(p.shape):
                vr = beta2 * f["vr"] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * f["vc"] + (1 - beta2) * g2.mean(-2)
                vr_hat = vr / jnp.maximum(vr.mean(-1, keepdims=True),
                                          self.eps)
                u = (gf * jax.lax.rsqrt(vr_hat + self.eps)[..., None]
                     * jax.lax.rsqrt(vc + self.eps)[..., None, :])
                nf = {"vr": vr, "vc": vc}
            else:
                v = beta2 * f["v"] + (1 - beta2) * g2
                u = gf * jax.lax.rsqrt(v + self.eps)
                nf = {"v": v}
            # update clipping (Shazeer & Stern eq. 9)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (-self.lr * u).astype(p.dtype), nf

        leaves = jax.tree.map(upd, grads, state["f"], params,
                              is_leaf=lambda x: isinstance(x, dict)
                              and ("vr" in x or "v" in x))
        updates = jax.tree.map(lambda o: o[0], leaves,
                               is_leaf=lambda x: isinstance(x, tuple))
        nf = jax.tree.map(lambda o: o[1], leaves,
                          is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"f": nf, "step": step}

    def state_pspecs(self, param_pspecs):
        """vr drops the last dim's spec entry; vc the second-to-last.
        (1D/scalar params are unfactored and inherit the param spec.)"""
        from jax.sharding import PartitionSpec as PS

        def leaf_spec(ps):
            parts = list(ps) if ps else []
            if len(parts) >= 2:
                return {"vr": PS(*parts[:-1]),
                        "vc": PS(*(parts[:-2] + parts[-1:]))}
            return {"v": ps if ps else PS()}

        return {"f": jax.tree.map(leaf_spec, param_pspecs,
                                  is_leaf=lambda x: isinstance(x, PS)),
                "step": PS()}


def make_optimizer(cfg, lr: float = 3e-4):
    if cfg.optimizer == "adafactor":
        return Adafactor(lr=lr)
    state_dtype = "float32"
    if cfg.param_count() > 5e10:
        state_dtype = "bfloat16"  # memory plan for 100B-class AdamW configs
    return AdamW(lr=lr, state_dtype=state_dtype)


def opt_state_pspecs(opt, param_pspecs):
    return opt.state_pspecs(param_pspecs)
