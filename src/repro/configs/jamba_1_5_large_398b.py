"""Jamba-1.5 Large 398B [arXiv:2403.19887] — hybrid Mamba:attention 7:1
interleave, MoE (16e top-2) every other layer.  SSM state decode => runs the
long_500k cell (the 9 attention layers use a model-axis-sharded KV cache).

Memory plan: optimizer=adafactor (factored second moment) — Adam fp32 m/v on
398B params would not fit 256 x 16GB; recorded in EXPERIMENTS §Dry-run."""
from repro.models.config import MambaCfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24_576, vocab=65_536,
    act="silu", glu=True, pos="none",  # jamba uses no positional encoding
    tie_embeddings=False,
    moe=MoECfg(num_experts=16, top_k=2, d_expert=24_576, every=2),
    mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
    attn_every=8,
    max_seq=1_048_576, supports_long_context=True,
    optimizer="adafactor",
    n_micro_override=16,  # §Perf iteration: -38% temp memory, flat terms
)
