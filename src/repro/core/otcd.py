"""TCD / OTCD query scheduling (paper §3–§4) over the device engines.

The schedule bookkeeping (which (ts, te) cells remain, per the three pruning
rules) is inherently sequential, tiny, and lives on host — it is factored
into ``core/scheduler.py`` (:class:`~repro.core.scheduler.QueryState`:
row cursors, IntervalSet pruning, empty-cell staircase, warm starts, TTI
dedup).  Every TCD operation (truncate + peel + TTI) is a single compiled
device program with dynamic window/threshold scalars — one compilation
serves the whole query.  All modes peel against a *windowed* TEL
(:meth:`TCQEngine._window_tel`, an LRU-cached power-of-two-bucketed
truncation) so per-cell peel work scales with the query window, not |E|.

Enumeration is over *unique* timestamps inside [Ts, Te] (column index space);
cells between adjacent real timestamps are exact duplicates of their
right-snap and are never scheduled (a strict, exact strengthening of PoR).

Two execution modes share that schedule:

* ``serial`` — paper-faithful: one cell per device program (`tcd.tcd`),
  decremental warm starts along each row (Theorem 1).
* ``wave`` — the device-resident lane pool (`engine.WavePipeline`): a
  persistent donated [W, V] lane buffer, one fused ``wave_step`` (peel +
  TTI + stats + uint32 bitmask pack) per batch of schedule cells with
  per-lane (ts, te, k, h), packed O(W·V/32) result transfer with deferred
  bulk decode, and a depth-D slot ring so host pruning bookkeeping
  overlaps device compute.  The Pallas ``banded_segsum`` degree closures
  are built once per engine (epoch).

(The seed stepwise engine — one blocking host round-trip per step — served
as the pipeline's benchmark baseline through PR 2 and was retired once the
BENCH_wave.json trajectory had cross-PR history; ``bench_pipeline`` now
gates wave mode against the serial engine.)

**Streaming.**  The engine is *epoch-versioned*: ``update_graph`` installs
a new immutable snapshot (produced by ``TemporalGraph.add_edges``'s
incremental merge-append), bumps ``engine.epoch``, and refreshes the
device TEL inside power-of-two *capacity classes* — edge/pair/vertex
buffers are sentinel-padded to capacities that only grow by doubling, so
a streaming append almost never changes a compiled program's shapes.
``_window_tel`` is keyed by ``(epoch, Ts, Te)`` and each cache entry pins
the TEL *and* the degree closures it was built with, so a graph update
can never serve a stale truncation to a new query nor a fresh truncation
to a query pinned to an older epoch (snapshot consistency — the contract
``core/service.py``'s mid-flight admission is built on).

:meth:`TCQEngine.query_batch` serves *many* queries through one shared
lane pool off a single union-window TEL; the streaming
:class:`~repro.core.service.TCQService` goes further — window-clustered
pools with mid-flight admission — and uses this engine underneath.
"""

from __future__ import annotations

import time
from collections import OrderedDict, defaultdict
from typing import Dict, List, Mapping, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tcd as tcd_mod
from repro.core.engine import WavePipeline
from repro.core.graph import DeviceTEL, TemporalGraph, pow2_capacity
from repro.core.intervals import IntervalSet
from repro.core.results import CoreResult, QueryStats, TCQResult
from repro.core.scheduler import QueryState, autotune_wave

_I32_MAX = np.iinfo(np.int32).max
_I32_MIN = np.iinfo(np.int32).min
_WINDOW_CACHE_MAX = 64
_EPOCH_AUX_MAX = 8          # snapshot pair-table LRU (epochs still in flight)


class WindowTEL(NamedTuple):
    """One window-truncated TEL plus everything needed to peel it.

    The degree closures and the device vertex width are *pinned per
    entry*: they were built against this entry's capacity classes, so a
    later capacity growth (or epoch bump) can never mix a cached TEL
    with incompatible closures.
    """

    tel: DeviceTEL
    seg_pair: object         # edge->pair segsum closure for this TEL
    seg_vert: object         # halfpair->vertex segsum closure
    num_vertices: int        # device vertex width (capacity, >= live V)
    window_edges: int        # live (non-sentinel) edges inside the window
    step_fn: object = None   # pinned wave step (make_wave_step_fn closure)


class _EpochAux(NamedTuple):
    """Per-epoch pair-table device arrays + closures (capacity padded)."""

    pair_u: object
    pair_v: object
    hp_src: object
    hp_pair: object
    seg_pair_full: object    # full-graph pair closure (XLA path reuse)
    seg_vert: object
    pair_cap: int
    v_cap: int


class TCQEngine:
    """Holds the device TEL + compiled TCD programs for one temporal graph.

    ``use_kernel`` selects the device step for wave mode: True forces
    the Pallas paths — the fused peel-to-fixpoint wave kernel
    (``kernels/wave_peel``) plus the banded segsum closures (interpret
    mode off-TPU) — False the XLA composite / segment-sum reference,
    None (default) auto-dispatches.  The closures — including the
    kernels' host-side band analyses — are built once per engine epoch
    (full TEL) or per cached window truncation and reused by every wave
    query on this engine.

    The engine is streaming-capable: :meth:`update_graph` installs a new
    graph snapshot under a fresh epoch.  ``num_vertices`` is the *device*
    vertex width (a capacity ≥ the live vertex count once the graph has
    grown past its initial size); padded vertices have no incident edges,
    peel out on the first fixpoint iteration for any k >= 1, and never
    appear in results.
    """

    def __init__(self, graph: TemporalGraph, degree_fn=None, *,
                 use_kernel: Optional[bool] = None,
                 resilience=None, cache=None,
                 mesh=None, combine: str = "auto"):
        from repro.kernels.segdeg.ops import on_tpu
        from repro.core.wave import ResilienceConfig
        from repro.core.corecache import CoreCache

        self._degree_fn = degree_fn
        # mesh=(jax Mesh) shards the wave path: edges over the mesh's
        # "model" axis, query lanes over pod x data (core/distributed.py).
        # The serial path, the TCD primitives and every cache stay
        # single-device — the mesh only changes who executes the peel.
        # combine: "psum" | "rs_ag" | "auto" (pick from V and lane count,
        # scheduler.choose_combine) — the degree-combine collective.
        self.mesh = mesh
        if mesh is not None:
            from repro.core.distributed import mesh_shard_counts

            self._lane_shards, self._model_shards = mesh_shard_counts(mesh)
            self._dist = {"pool_runs": 0, "device_steps": 0,
                          "collective_bytes": 0}
        else:
            self._lane_shards = self._model_shards = 1
            self._dist = None
        self._combine_req = combine
        self._combine = None
        self._shard_plan = None
        self._plan_arrays = None
        # cache=True builds a default TTI-keyed core-result cache
        # (corecache.CoreCache); an instance is used as-is; None/False
        # disables result caching (the default for bare engines — the
        # streaming service enables it for engines it owns).  Cached
        # results are only sound for the standard distinct-neighbour
        # degree, so a custom degree_fn forces the cache off.
        if cache is True:
            cache = CoreCache()
        self.core_cache = (cache or None) if degree_fn is None else None
        self._use_kernel = on_tpu() if use_kernel is None else use_kernel
        # resilience=True (or a ResilienceConfig) pins a degradation
        # ladder (Pallas -> XLA -> numpy oracle; demotion on VMEM/compile
        # failure or a sampled divergence tripwire) as every window's
        # step_fn instead of the single-lowering dispatch.  Ladder rungs
        # never donate the lane buffer (failed calls replay one rung
        # down bit-identically), so resilient mode trades the donated
        # in-place lane update for fault containment.
        if resilience is True:
            resilience = ResilienceConfig()
        self._resilience: Optional[ResilienceConfig] = resilience or None
        self.epoch = 0
        # (epoch, Ts, Te) -> WindowTEL, LRU
        self._win_cache: "OrderedDict[Tuple[int, int, int], WindowTEL]" = \
            OrderedDict()
        self._win_hits = 0
        self._win_misses = 0
        self._win_evictions = 0
        # epoch -> _EpochAux, LRU (snapshots with queries still in flight)
        self._epoch_aux: "OrderedDict[int, _EpochAux]" = OrderedDict()
        self._install(graph, initial=True)

    # ------------------------------------------------------------- streaming
    def _install(self, graph: TemporalGraph, initial: bool) -> None:
        """(Re)build the device TEL inside the engine's capacity classes.

        Initial capacities are exact (a static graph pays zero padding);
        once streaming appends outgrow a capacity it jumps to the next
        power of two, so recompiles are amortized O(1) over a stream and
        shapes are shared across epochs in the same capacity class.
        """
        from repro.kernels.segdeg.ops import make_banded_segsum

        if initial:
            self._edge_cap = graph.num_edges
            self._pair_cap = graph.num_pairs
            self._v_cap = graph.num_vertices
            grew_pairs = grew_verts = True
        else:
            grew_pairs = graph.num_pairs > self._pair_cap
            grew_verts = graph.num_vertices > self._v_cap
            if graph.num_edges > self._edge_cap:
                self._edge_cap = pow2_capacity(graph.num_edges)
            if grew_pairs:
                self._pair_cap = pow2_capacity(graph.num_pairs)
            if grew_verts:
                self._v_cap = pow2_capacity(graph.num_vertices)
        if self.mesh is not None:
            # one vertex width everywhere: the sharded step needs V to be
            # a multiple of 8*model_shards (byte-aligned alive slices per
            # shard), and the single-device TEL must agree — its hp_src
            # sentinel is v_cap, which must sit at the shared width's
            # dropped segment, not inside a wider sharded degree slice
            from repro.core.distributed import ShardPlan

            self._v_cap = ShardPlan._round_vertices(self._v_cap,
                                                    self._model_shards)
        self.graph = graph
        arrs = graph.tel_arrays(edge_capacity=self._edge_cap,
                                pair_capacity=self._pair_cap,
                                vertex_capacity=self._v_cap)
        self.tel = DeviceTEL(**{k: jnp.asarray(v) for k, v in arrs.items()})
        if initial or grew_verts:
            self.num_vertices = self._v_cap
            self._ones = jnp.ones((self._v_cap,), dtype=bool)
        # closures are capacity-shaped but id-dependent (the Pallas band
        # analysis follows the segment ids), so they refresh per epoch;
        # the XLA path's partials are free to rebuild
        self._seg_pair = make_banded_segsum(
            arrs["pair_id"], self._pair_cap, use_kernel=self._use_kernel)
        self._seg_vert = make_banded_segsum(
            arrs["hp_src"], self._v_cap, use_kernel=self._use_kernel)
        aux = _EpochAux(self.tel.pair_u, self.tel.pair_v, self.tel.hp_src,
                        self.tel.hp_pair, self._seg_pair, self._seg_vert,
                        self._pair_cap, self._v_cap)
        self._remember_aux(self.epoch, aux)
        if self.mesh is not None:
            self._install_shards(graph, initial)

    def _install_shards(self, graph: TemporalGraph, initial: bool) -> None:
        """Build or in-place refresh the frozen-ownership shard plan and
        re-place the full-graph edge shards on the mesh.  In the
        streaming steady state (no capacity growth) ``refresh`` keeps
        every buffer shape, so the compiled sharded step — keyed on
        (mesh, v_cap, p_cap, combine) plus the edge-cap bucket — carries
        across epochs with zero recompiles."""
        from repro.core.distributed import ShardPlan, wave_shardings
        from repro.core.scheduler import choose_combine

        if initial or self._shard_plan is None:
            self._shard_plan = ShardPlan.build(graph, self._model_shards,
                                               vertex_capacity=self._v_cap)
        else:
            self._shard_plan.refresh(graph, vertex_capacity=self._v_cap)
        plan = self._shard_plan
        assert plan.num_vertices == self._v_cap
        sh = wave_shardings(self.mesh, plan.num_vertices, plan.num_shards)
        self._edges_sharding = sh["edges"]
        self._plan_arrays = tuple(
            jax.device_put(a, sh["edges"])
            for a in (plan.src, plan.dst, plan.t, plan.pair_local,
                      plan.hp_src, plan.hp_pair))
        if self._combine_req == "auto":
            # nominal wave of 32 lanes: the choice only flips on V, and
            # pinning it here keeps one compiled program per capacity
            # class instead of one per autotuned W
            self._combine = choose_combine(self._v_cap, 32,
                                           self._model_shards)
        else:
            self._combine = self._combine_req

    def _sharded_step(self, arrays, tel, Ts: int, Te: int, *, full: bool):
        """The sharded device step (or ladder) for one window entry.
        ``arrays`` are the mesh-placed edge shards, ``tel`` the matching
        single-device window TEL (serial mode, the ladder's oracle rung,
        and the kernel-within-shard build all read it)."""
        from repro.core.distributed import (ShardedDegradationLadder,
                                            make_sharded_kernel_step,
                                            make_sharded_step_fn)

        plan = self._shard_plan
        if self._resilience is not None:
            return ShardedDegradationLadder(
                self.mesh, arrays, tel, self._v_cap, p_cap=plan.p_cap,
                combine=self._combine, use_kernel=self._use_kernel,
                config=self._resilience)
        if self._use_kernel and self._model_shards == 1:
            step = make_sharded_kernel_step(self.mesh, tel, self._v_cap)
            if step is not None:
                return step
        return make_sharded_step_fn(
            self.mesh, arrays, num_vertices=self._v_cap, p_cap=plan.p_cap,
            combine=self._combine, donate=True)

    def update_graph(self, graph: TemporalGraph) -> int:
        """Install a new graph snapshot (streaming append) under a fresh
        epoch; returns the new epoch.  In-flight queries pinned to older
        epochs are untouched — their window TELs (and the snapshots they
        were truncated from) stay valid and epoch-keyed.  Host cost is
        O(E) array padding; device programs recompile only when a
        capacity class grows (amortized O(1) by doubling).

        When the new snapshot is the direct child of the current one
        (``graph.parent_uid`` matches and the appended batch's time span
        is known), the core-result cache is *advanced*, not flushed:
        entries the batch cannot affect are re-keyed to the new epoch,
        entries it can are invalidated (see CoreCache.advance_epoch).  An
        unrelated snapshot simply starts the new epoch cold — entries at
        older epochs stay valid for queries still pinned to them."""
        old_epoch, old_uid = self.epoch, self.graph.uid
        self.epoch += 1
        self._install(graph, initial=False)
        if self.core_cache is not None:
            span = getattr(graph, "appended_span", None)
            if span is not None and \
                    getattr(graph, "parent_uid", None) == old_uid:
                self.core_cache.advance_epoch(old_epoch, self.epoch,
                                              int(span[0]), int(span[1]))
        return self.epoch

    def _remember_aux(self, epoch: int, aux: _EpochAux) -> None:
        self._epoch_aux[epoch] = aux
        self._epoch_aux.move_to_end(epoch)
        while len(self._epoch_aux) > _EPOCH_AUX_MAX:
            self._epoch_aux.popitem(last=False)

    def _aux_for(self, epoch: int, g: TemporalGraph) -> _EpochAux:
        """Pair-table device arrays + closures for one epoch's snapshot,
        padded to the engine's *current* capacity classes (snapshots are
        ancestors of the current graph, so they always fit)."""
        from repro.kernels.segdeg.ops import make_banded_segsum

        hit = self._epoch_aux.get(epoch)
        if hit is not None:
            self._epoch_aux.move_to_end(epoch)
            return hit
        if g.num_pairs > self._pair_cap or g.num_vertices > self._v_cap:
            raise ValueError(
                "snapshot exceeds engine capacities — not an ancestor of "
                "the engine's current graph")
        arrs = g.tel_arrays(pair_capacity=self._pair_cap,
                            vertex_capacity=self._v_cap)
        aux = _EpochAux(
            jnp.asarray(arrs["pair_u"]), jnp.asarray(arrs["pair_v"]),
            jnp.asarray(arrs["hp_src"]), jnp.asarray(arrs["hp_pair"]),
            make_banded_segsum(arrs["pair_id"], self._pair_cap,
                               use_kernel=self._use_kernel),
            make_banded_segsum(arrs["hp_src"], self._v_cap,
                               use_kernel=self._use_kernel),
            self._pair_cap, self._v_cap)
        self._remember_aux(epoch, aux)
        return aux

    def retire_epochs(self, live_epochs) -> int:
        """Evict window-TEL and pair-table cache entries for epochs no
        longer pinned by any in-flight or pending ticket.

        The window LRU is size-bounded but not epoch-aware: a retired
        epoch's WindowTELs (device edge buffers + pinned closures) used
        to sit in the cache pinning device memory until capacity eviction
        pushed them out.  The streaming service calls this after every
        pool with the set of epochs still pinned; the engine's current
        epoch is always kept.  Returns the number of evicted entries.
        """
        live = {int(e) for e in live_epochs}
        live.add(self.epoch)
        dead_w = [k for k in self._win_cache if k[0] not in live]
        for k in dead_w:
            del self._win_cache[k]
        dead_a = [e for e in self._epoch_aux if e not in live]
        for e in dead_a:
            del self._epoch_aux[e]
        if self.core_cache is not None:
            self.core_cache.retire_epochs(live)
        return len(dead_w) + len(dead_a)

    def rebase_epoch(self, epoch: int) -> None:
        """Re-key the engine's current snapshot under an externally
        dictated epoch number (crash recovery: a restored service resumes
        its pre-crash epoch numbering, so re-admitted tickets' pinned
        epochs stay meaningful and later pushes continue the sequence)."""
        epoch = int(epoch)
        if epoch == self.epoch:
            return
        aux = self._epoch_aux.pop(self.epoch)
        moved = [(k, v) for k, v in self._win_cache.items()
                 if k[0] == self.epoch]
        for k, _ in moved:
            del self._win_cache[k]
        if self.core_cache is not None:
            self.core_cache.rebase_epoch(self.epoch, epoch)
        self.epoch = epoch
        self._epoch_aux[epoch] = aux
        for (_, ts, te), v in moved:
            self._win_cache[(epoch, ts, te)] = v

    def resilience_events(self) -> List[Dict]:
        """Degradation events (demotions, unavailable rungs) across every
        live window ladder, most recent windows last.  Empty when the
        engine was built without ``resilience``."""
        out: List[Dict] = []
        for (ep, ts, te), wt in self._win_cache.items():
            for ev in getattr(wt.step_fn, "events", ()):
                out.append({"epoch": ep, "window": (ts, te), **ev})
        return out

    # -------------------------------------------------------- window slicing
    def _window_tel(self, Ts: int, Te: int, *,
                    graph: Optional[TemporalGraph] = None,
                    epoch: Optional[int] = None) -> WindowTEL:
        """Device TEL truncated to [Ts, Te] for one epoch's snapshot.

        Every cell of a query's schedule lies inside [Ts, Te], so both the
        serial engine and the wave pipeline peel against only the window's
        edges — per-iteration work scales with the window, not |E|.  Edge
        arrays are padded to a power-of-two bucket with sentinel edges
        (t=int32 min, pair_id=pair capacity, ignored by both degree
        paths), so compiled programs are shared across windows of similar
        size; the vertex-side segsum closure is capacity-shaped and
        always reused.  On the XLA degree path the pair-side closure is
        reused too (it only fixes num_segments); the Pallas path rebuilds
        it because its k_max band analysis depends on the windowed segment
        ids.  The cache is LRU and keyed by ``(epoch, Ts, Te)``: a graph
        update can never serve a stale truncation (new epoch, new key),
        while queries pinned to an older epoch — pass ``graph``/``epoch``
        explicitly — keep hitting their snapshot's entries.  Each entry
        pins the closures and device vertex width it was built with.
        """
        g = self.graph if graph is None else graph
        ep = self.epoch if epoch is None else int(epoch)
        key = (ep, int(Ts), int(Te))
        hit = self._win_cache.get(key)
        if hit is not None:
            self._win_hits += 1
            self._win_cache.move_to_end(key)
            return hit
        self._win_misses += 1
        from repro.core.wave import make_wave_step_fn

        aux = self._aux_for(ep, g)
        idx = np.flatnonzero((g.t >= Ts) & (g.t <= Te))
        e = int(idx.size)
        donate = self._resilience is None
        if ep == self.epoch and e >= g.num_edges:
            if self.mesh is not None:
                step = self._sharded_step(self._plan_arrays, self.tel,
                                          Ts, Te, full=True)
            else:
                step = make_wave_step_fn(self.tel, self._v_cap,
                                         seg_pair=self._seg_pair,
                                         seg_vert=self._seg_vert,
                                         use_kernel=self._use_kernel,
                                         donate=donate,
                                         resilience=self._resilience)
            out = WindowTEL(self.tel, self._seg_pair, self._seg_vert,
                            self._v_cap, e, step)
        else:
            bucket = pow2_capacity(e)
            pad = bucket - e
            # sentinel timestamp must be below every representable window
            # (t = -1 would collide with graphs using negative timestamps);
            # sentinel pair id = pair capacity (dropped by the scatter)
            t_w = np.concatenate(
                [g.t[idx], np.full(pad, _I32_MIN, np.int32)])
            pid_w = np.concatenate(
                [g.pair_id[idx], np.full(pad, aux.pair_cap, np.int32)])
            tel = DeviceTEL(
                src=jnp.asarray(np.concatenate(
                    [g.src[idx], np.zeros(pad, np.int32)])),
                dst=jnp.asarray(np.concatenate(
                    [g.dst[idx], np.zeros(pad, np.int32)])),
                t=jnp.asarray(t_w),
                pair_id=jnp.asarray(pid_w),
                pair_u=aux.pair_u,
                pair_v=aux.pair_v,
                hp_src=aux.hp_src,
                hp_pair=aux.hp_pair,
                time_perm=jnp.asarray(
                    np.argsort(t_w, kind="stable").astype(np.int32)),
            )
            if self._use_kernel:
                from repro.kernels.segdeg.ops import make_banded_segsum

                seg_pair = make_banded_segsum(pid_w, aux.pair_cap,
                                              use_kernel=True)
            else:
                seg_pair = aux.seg_pair_full
            # pin the fused (or composite) wave step per cache entry: the
            # fused kernel's host-side band tables follow this truncation's
            # segment ids, so they are built once per (epoch, Ts, Te) and
            # shared by every pipeline that peels this window
            if self.mesh is not None:
                plan = self._shard_plan
                sharr = plan.window_arrays(g, int(Ts), int(Te))
                hp = plan.hp_arrays(g)
                arrays = tuple(jax.device_put(a, self._edges_sharding)
                               for a in sharr + hp)
                step = self._sharded_step(arrays, tel, Ts, Te, full=False)
            else:
                step = make_wave_step_fn(tel, aux.v_cap, seg_pair=seg_pair,
                                         seg_vert=aux.seg_vert,
                                         use_kernel=self._use_kernel,
                                         donate=donate,
                                         resilience=self._resilience)
            out = WindowTEL(tel, seg_pair, aux.seg_vert, aux.v_cap, e, step)
        if len(self._win_cache) >= _WINDOW_CACHE_MAX:
            self._win_cache.popitem(last=False)     # evict least-recent
            self._win_evictions += 1
        self._win_cache[key] = out
        return out

    # ------------------------------------------------------------ pool seam
    def make_pool(self, lo: int, hi: int, *,
                  graph: Optional[TemporalGraph] = None,
                  epoch: Optional[int] = None, num_queries: int = 1,
                  wave: Union[int, str] = "auto", depth: int = 2):
        """Window TEL + lane pipeline for one pool run — the single seam
        ``query``/``query_batch``/``TCQService.pump`` build pools
        through, so the mesh routing decision lives in one place.

        Returns ``(pipe, wt, wave)``: on a plain engine a
        :class:`~repro.core.engine.WavePipeline` over the window's
        single-device step; on a mesh engine a
        :class:`~repro.core.distributed.ShardedWavePipeline` over the
        shard_map step, with W autotuned (or rounded up) to a multiple
        of the lane-axis size.
        """
        wt = self._window_tel(int(lo), int(hi), graph=graph, epoch=epoch)
        if self.mesh is None:
            if wave == "auto":
                wave = autotune_wave(wt.num_vertices, wt.window_edges,
                                     num_queries=num_queries, depth=depth)
            pipe = WavePipeline(wt.tel, wt.num_vertices, wt.seg_pair,
                                wt.seg_vert, wave, depth,
                                step_fn=wt.step_fn)
            return pipe, wt, wave
        from repro.core.distributed import ShardedWavePipeline

        L = self._lane_shards
        if wave == "auto":
            wave = autotune_wave(wt.num_vertices, wt.window_edges,
                                 num_queries=num_queries, depth=depth,
                                 lane_shards=L)
        else:
            wave = -(-int(wave) // L) * L   # even lane split per shard
        pipe = ShardedWavePipeline(wt.step_fn, mesh=self.mesh,
                                   num_vertices=wt.num_vertices,
                                   wave=wave, depth=depth,
                                   dist_counters=self._dist)
        return pipe, wt, wave

    # --------------------------------------------------------- observability
    def stats(self) -> Dict:
        """Engine-level cache observability: the window-TEL LRU's
        hit/miss/eviction counters and, when result caching is on, the
        TTI core cache's counters (see CoreCache.stats)."""
        out = {
            "epoch": self.epoch,
            "window_tel": {
                "hits": self._win_hits,
                "misses": self._win_misses,
                "evictions": self._win_evictions,
                "size": len(self._win_cache),
            },
        }
        if self.core_cache is not None:
            out["core_cache"] = self.core_cache.stats()
        if self.mesh is not None:
            out["distributed"] = {
                "mesh": dict(zip(self.mesh.axis_names,
                                 self.mesh.devices.shape)),
                "devices": int(self.mesh.devices.size),
                "lane_shards": self._lane_shards,
                "model_shards": self._model_shards,
                "combine": self._combine,
                **self._dist,
            }
        return out

    def _cache_view(self, k: int, h: int, epoch: Optional[int] = None):
        """CacheView bound to (epoch, k, h), or None when caching is off."""
        from repro.core.corecache import CacheView

        if self.core_cache is None:
            return None
        return CacheView(self.core_cache,
                         self.epoch if epoch is None else int(epoch),
                         k, h)

    # ------------------------------------------------------------- primitives
    def _tcd(self, alive, ts, te, k, h, wt: Optional[WindowTEL] = None):
        tel = self.tel if wt is None else wt.tel
        nv = self.num_vertices if wt is None else wt.num_vertices
        return tcd_mod.tcd(tel, alive, ts, te, k, h, num_vertices=nv,
                           degree_fn=self._degree_fn)

    # ------------------------------------------------------------------ query
    def query(self, k: int, Ts: int, Te: int, *, h: int = 1,
              algorithm: str = "otcd", mode: str = "serial",
              wave: Union[int, str] = 8, depth: int = 2,
              min_span: Optional[int] = None,
              max_span: Optional[int] = None) -> TCQResult:
        """All distinct temporal k-cores over subintervals of [Ts, Te].

        algorithm: "otcd" (TTI pruning, §4) or "tcd" (full enumeration, §3).
        mode: "serial" (paper-faithful) or "wave" (device-resident lane
        pool — up to ``wave`` schedule cells per fused device step,
        ``depth`` steps in flight).
        wave: lane count for wave mode, or "auto" to pick it from the
        vertex count, the windowed edge count and the ring depth
        (scheduler.autotune_wave).
        depth: slot-ring depth D for wave mode (pipelining; pruning seen
        by in-flight steps is up to D-1 steps stale, still exact).
        h: link-strength lower bound (paper §6.2); 1 = plain TCQ.
        min_span/max_span: time-span constraint (paper §6.2), applied on the
        fly; pruning stays exact because it is TTI-based.
        """
        if mode not in ("serial", "wave"):
            raise ValueError(
                f"unknown mode {mode!r}: expected 'serial' or 'wave' (the "
                "seed 'wave_stepwise' baseline was retired after PR 2)")
        t0 = time.perf_counter()
        uts = self.graph.unique_ts
        uts = uts[(uts >= Ts) & (uts <= Te)].astype(np.int64)
        n = int(uts.size)
        stats = QueryStats(n_timestamps=n, cells_total=n * (n + 1) // 2)
        if n == 0:
            return TCQResult([], stats)
        prune = algorithm == "otcd"
        if mode == "wave" and self._degree_fn is not None:
            # custom degree semantics are only plumbed through the scalar
            # TCD path; run serial (which honors degree_fn) rather than
            # silently ignoring the override
            mode = "serial"
        if mode == "wave":
            pipe, wt, wave = self.make_pool(int(uts[0]), int(uts[-1]),
                                            wave=wave, depth=depth)
            stats.window_edges = wt.window_edges
            cores = pipe.run(uts, k, h, prune, stats,
                             cache=self._cache_view(k, h))
        elif self._degree_fn is not None:
            # custom degree fns are written against the graph's real TEL
            # layout — never hand them the bucket-padded window truncation
            stats.window_edges = self.graph.num_edges
            cores = self._run_serial(uts, k, h, prune, stats)
        else:
            # serial peels against the same windowed TEL as wave mode:
            # per-cell work scales with the window's edges, not |E|
            wt = self._window_tel(int(uts[0]), int(uts[-1]))
            stats.window_edges = wt.window_edges
            cores = self._run_serial(uts, k, h, prune, stats, wt)
        out = list(cores.values())
        stats.wall_time_s = time.perf_counter() - t0
        res = TCQResult(out, stats)
        if min_span is not None or max_span is not None:
            res = res.filter_span(min_span, max_span)
        return res

    # ------------------------------------------------------------ query batch
    def query_batch(self, requests: Sequence[Mapping], *,
                    algorithm: str = "otcd", wave: Union[int, str] = "auto",
                    depth: int = 2) -> List[TCQResult]:
        """Serve many concurrent TCQ queries through one shared lane pool.

        ``requests`` is a sequence of mappings with keys ``k``, ``ts``,
        ``te`` and optionally ``h`` (default 1) — the format produced by
        ``repro.data.TCQRequestStream``.  Each request gets its own
        :class:`~repro.core.scheduler.QueryState` (private pruning, warm
        starts, TTI dedup), while the lane pool packs ready cells from
        every in-flight query into shared fused steps with per-lane
        (ts, te, k, h).  One TEL truncated to the *union* window serves
        the whole batch; per-lane windows keep each query's exact
        semantics, so every returned ``TCQResult`` is bit-identical to
        running that query alone.  Throughput improves because lanes
        freed by one query's draining tail are refilled with another's
        cells instead of idling — best when the batch's windows overlap
        (a serving hot set): per-iteration peel cost scales with the
        *union* window's edges, so batching a few narrow windows from
        opposite ends of a long timeline can cost more than looping
        ``query()``.  The streaming :class:`~repro.core.service.TCQService`
        automates exactly that grouping (window-clustered pools with
        mid-flight admission); this method remains the single-pool,
        fixed-batch entry point.

        Per-query ``QueryStats`` carry that query's schedule counters;
        pipeline counters (device_steps, host_syncs, occupancy, ...)
        describe the shared batch and are reported on every member (see
        :class:`~repro.core.results.QueryStats`).

        wave: lane count, or "auto" (default) — autotuned from the vertex
        count, the union window's edge count, the batch size and depth.
        depth: slot-ring depth D (D steps in flight).
        """
        t0 = time.perf_counter()
        reqs = [dict(r) for r in requests]
        prune = algorithm == "otcd"
        if self._degree_fn is not None:
            # custom degree semantics: fall back to per-query scheduling
            # (the scalar TCD path honors degree_fn; the fused wave step
            # does not)
            return [self.query(int(r["k"]), int(r["ts"]), int(r["te"]),
                               h=int(r.get("h", 1)), algorithm=algorithm)
                    for r in reqs]
        outs: List[Optional[TCQResult]] = [None] * len(reqs)
        states: List[Tuple[int, QueryState]] = []
        for qi, r in enumerate(reqs):
            uts = self.graph.unique_ts
            uts = uts[(uts >= int(r["ts"])) & (uts <= int(r["te"]))]
            uts = uts.astype(np.int64)
            n = int(uts.size)
            stats = QueryStats(n_timestamps=n,
                               cells_total=n * (n + 1) // 2,
                               batch_size=len(reqs))
            if n == 0:
                outs[qi] = TCQResult([], stats)
                continue
            states.append((qi, QueryState(
                uts, int(r["k"]), int(r.get("h", 1)), prune, stats,
                qid=qi,
                cache=self._cache_view(int(r["k"]), int(r.get("h", 1))))))
        if states:
            lo = min(int(s.uts[0]) for _, s in states)
            hi = max(int(s.uts[-1]) for _, s in states)
            pipe, wt, wave = self.make_pool(lo, hi,
                                            num_queries=len(states),
                                            wave=wave, depth=depth)
            pool_stats = QueryStats()
            pipe.run_pool([s for _, s in states], pool_stats)
            for qi, s in states:
                st = s.stats
                st.absorb_pool(pool_stats, window_edges=wt.window_edges,
                               batch_size=len(reqs))
                cores = s.decode_results(wt.num_vertices)
                outs[qi] = TCQResult(list(cores.values()), st)
        wall = time.perf_counter() - t0
        for out in outs:
            out.stats.wall_time_s = wall
        return outs

    # ----------------------------------------------------------- serial mode
    def _run_serial(self, uts, k, h, prune, stats,
                    wt: Optional[WindowTEL] = None):
        n = uts.size
        idx_of = {int(t): i for i, t in enumerate(uts)}
        pruned: Dict[int, IntervalSet] = defaultdict(IntervalSet)
        results: Dict[Tuple[int, int], CoreResult] = {}
        ones = self._ones if wt is None or \
            wt.num_vertices == self._ones.shape[0] \
            else jnp.ones((wt.num_vertices,), dtype=bool)
        empty_col_max = -1          # cells (r, c<=bound) are provably empty
        row_alive = None            # warm start across rows (Theorem 1)
        row_alive_j = -1
        for i in range(n):
            iv = pruned.pop(i, IntervalSet())
            j: Optional[int] = n - 1
            cur_alive = None
            first_in_row = True
            while j is not None and j >= i:
                j = iv.highest_uncovered_leq(j)
                if j is None or j < i:
                    break
                if j <= empty_col_max:
                    stats.cells_trivial += (j - i + 1) - iv.total_covered(i, j)
                    break
                if cur_alive is not None:
                    warm = cur_alive
                elif row_alive is not None and j <= row_alive_j:
                    warm = row_alive
                else:
                    warm = ones
                res = self._tcd(warm, int(uts[i]), int(uts[j]), k, h, wt)
                stats.cells_evaluated += 1
                stats.device_steps += 1
                if int(res.n_edges) == 0:
                    if j > i:
                        stats.pruned_empty += (j - i) - iv.total_covered(i, j - 1)
                    empty_col_max = max(empty_col_max, j)
                    if j == n - 1:
                        # T[ts_i, Te] empty => all deeper rows empty
                        stats.cells_trivial += sum(
                            n - r for r in range(i + 1, n))
                        return results
                    break
                cur_alive = res.alive
                if first_in_row:
                    row_alive, row_alive_j = res.alive, j
                    first_in_row = False
                a_idx = idx_of[int(res.tti_lo)]
                b_idx = idx_of[int(res.tti_hi)]
                self._collect(results, res, a_idx, b_idx, uts, k, stats)
                if prune:
                    if b_idx < j:                       # Rule 1: PoR
                        stats.por_triggers += 1
                        stats.pruned_por += (j - b_idx) - iv.total_covered(
                            b_idx, j - 1)
                    if a_idx > i:                       # Rule 2: PoU
                        stats.pou_triggers += 1
                        for r in range(i + 1, a_idx + 1):
                            stats.pruned_pou += pruned[r].add(r, j)
                    if a_idx > i and b_idx < j:         # Rule 3: PoL
                        stats.pol_triggers += 1
                        for r in range(a_idx + 1, b_idx + 1):
                            stats.pruned_pol += pruned[r].add(b_idx + 1, j)
                    j = (b_idx - 1) if b_idx < j else j - 1
                else:
                    j = j - 1
        return results

    # ---------------------------------------------------------------- collect
    def _collect(self, results, res, a_idx, b_idx, uts, k, stats):
        key = (int(uts[a_idx]), int(uts[b_idx]))
        if key in results:
            stats.duplicates += 1
            return
        alive = np.asarray(res.alive)          # full [V] bool transfer
        stats.host_syncs += 1
        stats.bytes_synced += alive.nbytes
        verts = np.flatnonzero(alive)
        results[key] = CoreResult(k=k, tti=key, vertices=verts,
                                  n_edges=int(res.n_edges))


def temporal_kcore_query(graph: TemporalGraph, k: int, Ts: int, Te: int,
                         **kw) -> TCQResult:
    """One-shot convenience wrapper (builds a throwaway engine)."""
    return TCQEngine(graph).query(k, Ts, Te, **kw)
