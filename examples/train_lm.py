"""Train a ~100M-parameter LM with the fault-tolerant distributed runtime.

Defaults are CPU-sized (a reduced qwen2-family model, a few steps) so the
example runs anywhere; ``--full`` selects the real ~100M config and a few
hundred steps (the deliverable-scale run; give it a real machine).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 20] [--full]
      [--fail-at 7]   # inject a node failure and watch the restart
"""

import argparse

from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.launch.mesh import make_host_mesh
from repro.runtime import FaultInjector, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--full", action="store_true",
                    help="~100M params, seq 512, few hundred steps")
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config("qwen2-7b")
    if args.full:
        cfg = base.scaled(n_layers=12, d_model=768, n_heads=12,
                          n_kv_heads=4, head_dim=64, d_ff=2048,
                          vocab=32_768, max_seq=512, dtype="float32")
        batch, seq = 8, 512
        steps = max(args.steps, 300)
    else:
        cfg = base.smoke().scaled(n_layers=4, d_model=128, d_ff=256)
        batch, seq = 4, 64
        steps = args.steps
    n_params = cfg.param_count()
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), "
          f"batch={batch} seq={seq} steps={steps}")

    mesh = make_host_mesh()
    data = SyntheticLMData(vocab=cfg.vocab, batch=batch, seq=seq, seed=0)
    injector = FaultInjector(
        fail_at={args.fail_at: "injected node loss"}
        if args.fail_at >= 0 else {})
    tr = Trainer(cfg, mesh, data,
                 TrainerConfig(steps=steps, ckpt_every=max(2, steps // 4),
                               ckpt_dir=args.ckpt, lr=3e-4),
                 injector=injector)
    out = tr.run()
    first = tr.metrics[0]["loss"]
    print(f"loss {first:.3f} -> {out['final_loss']:.3f} over "
          f"{out['steps_run']} logged steps; restarts={out['restarts']} "
          f"straggler_flags={out['straggler_flags']}")
    assert out["final_loss"] < first, "training should reduce loss"
    print("checkpoints at:", args.ckpt)


if __name__ == "__main__":
    main()
