"""HLO-text cost model with control-flow trip-count multipliers.

``compiled.cost_analysis()`` counts each while/scan body ONCE, which
undercounts a scanned-layer transformer by (groups x microbatches) — the
first dry-runs reported roofline fractions > 1, which is how this module
came to exist.  It walks the post-SPMD HLO text instead:

  * computations are parsed into blocks; ``while`` ops contribute their body
    cost multiplied by the trip count recovered from the loop condition's
    comparison constant (scan/fori loops lower to counted whiles);
  * matmul FLOPs: 2 * prod(output dims) * prod(contracting dims) per ``dot``;
  * HBM traffic: sum of (operand + output) bytes over top-level fusions /
    dots / copies / collectives — post-fusion, each op's operands/outputs
    are exactly the buffers that cross HBM;
  * collectives: operand bytes, replica-group size, and ring factor, also
    trip-multiplied.

All quantities are per-device (the post-SPMD module is the per-device
program).  Validated against analytic 6·N·D in tests/test_dryrun.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(r"=\s*(?:\([^)]*\)|[a-z][a-z0-9]*\[[^\]]*\]\S*)\s*"
                    r"([a-z][a-z0-9\-]*)\(")
_CALLED_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_SZ_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

# opcodes whose operands/outputs do NOT move HBM bytes
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "custom-call", "partition-id",
             "replica-id", "while", "conditional", "call"}


@dataclasses.dataclass
class Collective:
    kind: str
    operand_bytes: float
    group_size: int

    @property
    def ring_factor(self) -> float:
        g = max(self.group_size, 1)
        if self.kind == "all-reduce":
            return 2.0 * (g - 1) / g
        if self.kind == "collective-permute":
            return 1.0
        return (g - 1) / g

    @property
    def wire_bytes(self) -> float:
        return self.operand_bytes * self.ring_factor


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[Tuple[str, int], Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    # (called_computation, multiplier, kind) edges; kind "fusion" bodies are
    # in-register — they contribute flops but never HBM bytes
    edges: List[Tuple[str, object, str]] = dataclasses.field(
        default_factory=list)


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _line_shapes(line: str) -> List[Tuple[str, str]]:
    return _SHAPE_RE.findall(line)


def _line_bytes(line: str) -> float:
    return float(sum(_shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
                     for dt, dims in _line_shapes(line)))


def _dot_flops(line: str) -> float:
    m = _CONTRACT_RE.search(line)
    shapes = _line_shapes(line)
    if not shapes:
        return 0.0
    # output shape = first; lhs operand = second shape in the line
    out = _shape_elems(shapes[0][1])
    if m is None or len(shapes) < 2:
        return 2.0 * out
    lhs_dims = [int(x) for x in shapes[1][1].split(",") if x]
    cdims = [int(x) for x in m.group(1).split(",") if x]
    k = 1
    for c in cdims:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    return 2.0 * out * k


def _trip_count(cond_text: str) -> float:
    """Counted loops compare the induction variable against a constant."""
    consts = [int(x) for x in re.findall(r"constant\((\d+)\)", cond_text)]
    return float(max(consts)) if consts else 1.0


def split_computations(hlo: str) -> Dict[str, str]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    # header: "[ENTRY ]%name (params...) -> type {"  — params may contain
    # nested parens (tuple types), so only anchor on name + "->" + "{".
    entry_marker = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
    for line in hlo.splitlines():
        s = line.rstrip()
        if cur is None:
            m = entry_marker.match(s.strip())
            if m and s.strip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
        else:
            if s.strip() == "}":
                cur = None
            elif cur is not None:
                comps[cur].append(s)
    return {k: "\n".join(v) for k, v in comps.items()}


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
                     r"(\([^=]*?\)|[a-z][a-z0-9]*\[[0-9,]*\]\S*)")


def _shapes_bytes(shape_text: str) -> float:
    return float(sum(_shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
                     for dt, dims in _SHAPE_RE.findall(shape_text)))


def analyze_computation(text: str, shape_table: Dict[str, str]) -> CompCost:
    """shape_table: global op-name -> output type text (operands in this HLO
    dialect are bare %names, so shapes are resolved through definitions)."""
    local: Dict[str, str] = {}
    for line in text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            local[m.group(1)] = m.group(2)

    def resolve(name: str) -> str:
        return local.get(name) or shape_table.get(name, "")

    def operand_bytes_of(s: str, om_end: int) -> float:
        paren = s[om_end:]
        depth = 1
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    paren = paren[:i]
                    break
        inline = _SHAPE_RE.findall(paren)
        if inline:
            return float(sum(_shape_elems(d) * _DTYPE_BYTES.get(t, 4)
                             for t, d in inline))
        names = re.findall(r"%([\w\.\-]+)", paren)
        return float(sum(_shapes_bytes(resolve(n)) for n in names))

    c = CompCost()
    for line in text.splitlines():
        s = line.strip()
        om = _OP_RE.search(s)
        if not om:
            continue
        op = om.group(1)
        if op == "while":
            bm = _BODY_RE.search(s)
            cm = _COND_RE.search(s)
            # XLA records the static trip count of counted loops directly
            tm = re.search(r'known_trip_count[^}]*"n":"(\d+)"', s)
            if bm:
                if tm:
                    c.edges.append((bm.group(1), float(tm.group(1)), "loop"))
                else:
                    c.edges.append((bm.group(1), ("__cond__", cm.group(1))
                                    if cm else 1.0, "loop"))
            continue
        if op == "fusion":
            fm = _CALLED_RE.search(s)
            if fm:
                c.edges.append((fm.group(1), 1.0, "fusion"))
            continue  # bytes come from the body-aware fusion model
        if op == "call":
            fm = _CALLED_RE.search(s)
            if fm:
                c.edges.append((fm.group(1), 1.0, "call"))
        if op == "conditional":
            brm = _BRANCHES_RE.search(s)
            if brm:
                for b in brm.group(1).split(","):
                    c.edges.append((b.strip().lstrip("%"), 1.0, "call"))
        if op.startswith("all-") or op.startswith("reduce-scatter") or \
                op.startswith("collective-permute"):
            base = op.replace("-start", "")
            if base in _COLL_KINDS:
                ob = operand_bytes_of(s, om.end())
                gm = _GROUPS_RE.search(s)
                if gm:
                    gsz = len([x for x in gm.group(1).split(",")
                               if x.strip() != ""])
                else:
                    gm2 = _GROUPS_SZ_RE.search(s)
                    gsz = int(gm2.group(2)) if gm2 else 1
                key = (base, gsz)
                d = c.collectives.setdefault(
                    key, {"count": 0.0, "operand_bytes": 0.0})
                d["count"] += 1
                d["operand_bytes"] += ob
                dm = _DEF_RE.match(line)
                c.bytes += (_shapes_bytes(dm.group(2)) if dm else 0.0) + ob
            continue
        if op == "dot":
            dm = _DEF_RE.match(line)
            out_text = dm.group(2) if dm else ""
            out = float(sum(_shape_elems(d)
                            for _, d in _SHAPE_RE.findall(out_text)))
            cm = _CONTRACT_RE.search(s)
            # lhs operand is either inline-typed ("dot(f32[a,b]{..} %x, ...")
            # or a bare "%x" resolved through the definition table
            lhs_dims: List[int] = []
            lhs_inline = re.search(r"dot\(\s*[a-z][a-z0-9]*\[([0-9,]*)\]", s)
            if lhs_inline:
                lhs_dims = [int(x) for x in lhs_inline.group(1).split(",")
                            if x]
            else:
                lhs_name = re.search(r"dot\(\s*%([\w\.\-]+)", s)
                if lhs_name:
                    lhs_shapes = _SHAPE_RE.findall(resolve(lhs_name.group(1)))
                    if lhs_shapes:
                        lhs_dims = [int(x) for x in lhs_shapes[0][1].split(",")
                                    if x]
            k = 1.0
            if cm and lhs_dims:
                for cd in [int(x) for x in cm.group(1).split(",") if x]:
                    if cd < len(lhs_dims):
                        k *= lhs_dims[cd]
            c.flops += 2.0 * out * k
            c.bytes += (_shapes_bytes(out_text)
                        + operand_bytes_of(s, om.end()))
            continue
        if op in _FREE_OPS or op.endswith("-done"):
            continue
        dm = _DEF_RE.match(line)
        out_bytes = _shapes_bytes(dm.group(2)) if dm else 0.0
        if op in ("dynamic-slice", "gather", "slice"):
            # reads only the sliced/gathered region, not the whole operand
            c.bytes += 2.0 * out_bytes
        elif op in ("dynamic-update-slice", "scatter"):
            names = re.findall(r"%([\w\.\-]+)", s[om.end():])
            upd = _shapes_bytes(resolve(names[1])) if len(names) > 1 else 0.0
            c.bytes += 2.0 * upd
        else:
            c.bytes += out_bytes + operand_bytes_of(s, om.end())
    return c


class HLOCost:
    def __init__(self, hlo_text: str):
        self.comps = split_computations(hlo_text)
        shape_table: Dict[str, str] = {}
        for t in self.comps.values():
            for line in t.splitlines():
                m = _DEF_RE.match(line)
                if m:
                    shape_table.setdefault(m.group(1), m.group(2))
        self.costs = {name: analyze_computation(t, shape_table)
                      for name, t in self.comps.items()}
        self._memo: Dict[str, Tuple[float, float, Dict]] = {}
        self._fusion_memo: Dict[str, float] = {}
        # entry = the computation marked ENTRY
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
        self.entry = m.group(1) if m else next(iter(self.comps))
        f, b, coll = self._total(self.entry, set())
        self.flops = f
        self.bytes = b
        self.collectives = coll

    def _resolve_trips(self, edge_mult) -> float:
        if isinstance(edge_mult, tuple) and edge_mult[0] == "__cond__":
            cond = edge_mult[1]
            return _trip_count(self.comps.get(cond, ""))
        return float(edge_mult)

    def _total(self, name: str, stack) -> Tuple[float, float, Dict]:
        if name in self._memo:
            return self._memo[name]
        if name not in self.costs or name in stack:
            return 0.0, 0.0, {}
        stack = stack | {name}
        c = self.costs[name]
        f, b = c.flops, c.bytes
        coll: Dict[Tuple[str, int], Dict[str, float]] = {
            k: dict(v) for k, v in c.collectives.items()}
        for child, mult, kind in c.edges:
            m = self._resolve_trips(mult)
            cf, cb, cc = self._total(child, stack)
            f += m * cf
            if kind == "fusion":
                # fused bodies live in registers; HBM traffic is the
                # body-aware param/output model (slice-aware)
                b += m * self._fusion_traffic(child)
            else:
                b += m * cb
            for k, v in cc.items():
                d = coll.setdefault(k, {"count": 0.0, "operand_bytes": 0.0})
                d["count"] += m * v["count"]
                d["operand_bytes"] += m * v["operand_bytes"]
        self._memo[name] = (f, b, coll)
        return f, b, coll

    def _fusion_traffic(self, name: str) -> float:
        """HBM traffic of one fused kernel: each parameter is read in full
        UNLESS it is only consumed through dynamic-slice/gather (then only
        the slice moves); a dynamic-update-slice root writes only the
        update extent (the big buffer is aliased in place)."""
        if name in self._fusion_memo:
            return self._fusion_memo[name]
        text = self.comps.get(name, "")
        params: Dict[str, str] = {}
        uses: Dict[str, List[str]] = {}
        defs: Dict[str, str] = {}
        lines = text.splitlines()
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            defs[dm.group(1)] = dm.group(2)
            om = _OP_RE.search(line)
            op = om.group(1) if om else ""
            if op == "parameter":
                params[dm.group(1)] = dm.group(2)
            elif om:
                for ref in re.findall(r"%([\w\.\-]+)", line[om.end():]):
                    uses.setdefault(ref, []).append(
                        (op, dm.group(2)))
        traffic = 0.0
        for pname, ptype in params.items():
            consumers = uses.get(pname, [])
            if consumers and all(op in ("dynamic-slice", "gather", "slice")
                                 for op, _ in consumers):
                traffic += sum(_shapes_bytes(otype)
                               for _, otype in consumers)
            else:
                traffic += _shapes_bytes(ptype)
        root_line = next((ln for ln in lines
                          if ln.strip().startswith("ROOT")), "")
        rom = _OP_RE.search(root_line)
        root_op = rom.group(1) if rom else ""
        rdm = _DEF_RE.match(root_line.strip()) if root_line else None
        out_bytes = _shapes_bytes(rdm.group(2)) if rdm else 0.0
        if root_op in ("dynamic-update-slice", "scatter") and rom:
            opnames = re.findall(r"%([\w\.\-]+)", root_line[rom.end():])
            if len(opnames) > 1:
                upd = defs.get(opnames[1]) or params.get(opnames[1], "")
                out_bytes = _shapes_bytes(upd)
        self._fusion_memo[name] = traffic + out_bytes
        return self._fusion_memo[name]

    # ------------------------------------------------------------- summaries
    def while_bodies(self) -> Dict[str, Dict[str, float]]:
        """Per-iteration cost of every while-loop body in the module:
        ``{body: {"flops", "bytes", "trips", "dynamic"}}``.  A body's
        flops/bytes already fold in its *nested* counted loops (trip-
        multiplied), so summing the ``dynamic`` bodies gives the per-
        iteration cost of the data-dependent loops.  A dynamic-condition
        loop (a peel fixpoint) has no static trip count — ``self.bytes``
        counts its body once, and callers add ``(iters - 1) * bytes`` to
        model an N-iteration run: exactly the unfused-chain per-iteration
        HBM traffic the fused wave-peel kernel eliminates."""
        out: Dict[str, Dict[str, float]] = {}
        for cost in self.costs.values():
            for child, mult, kind in cost.edges:
                if kind == "loop" and child in self.costs:
                    f, b, _ = self._total(child, set())
                    trips = self._resolve_trips(mult)
                    out[child] = {"flops": f, "bytes": b, "trips": trips,
                                  "dynamic": isinstance(mult, tuple)
                                  and trips == 1.0}
        return out

    def shape_census(self, dims: Tuple[int, ...]) -> int:
        """Count HBM-crossing buffer materializations of one exact shape.

        Walks every non-fusion-body computation and counts op *results*
        (non-free opcodes) whose output shape matches ``dims`` — i.e. how
        many times a buffer of that shape is written to HBM somewhere in
        the program (loop bodies count once, not per trip).  Used by
        benchmarks/perf_lower.py to assert the unfused peel chain
        materializes [W, E] edge-activity arrays while the fused lowering
        has none."""
        want = ",".join(str(int(d)) for d in dims)
        fusion_bodies = {child for cost in self.costs.values()
                         for child, _m, kind in cost.edges
                         if kind == "fusion"}
        n = 0
        for name, text in self.comps.items():
            if name in fusion_bodies:
                continue  # in-register; never an HBM buffer
            for line in text.splitlines():
                om = _OP_RE.search(line)
                if not om or om.group(1) in _FREE_OPS:
                    continue
                dm = _DEF_RE.match(line)
                if not dm:
                    continue
                if any(d == want for _t, d in _SHAPE_RE.findall(dm.group(2))):
                    n += 1
        return n

    def collective_ops(self) -> List[Collective]:
        out = []
        for (kind, gsz), v in self.collectives.items():
            out.append(Collective(kind, v["operand_bytes"], gsz))
        return out

    def collective_summary(self) -> Dict[str, Dict[str, float]]:
        summ: Dict[str, Dict[str, float]] = {}
        for (kind, gsz), v in self.collectives.items():
            c = Collective(kind, v["operand_bytes"], gsz)
            d = summ.setdefault(kind, {"count": 0.0, "operand_bytes": 0.0,
                                       "wire_bytes": 0.0})
            d["count"] += v["count"]
            d["operand_bytes"] += v["operand_bytes"]
            d["wire_bytes"] += c.wire_bytes
        return summ

    def wire_bytes(self) -> float:
        return sum(c.wire_bytes for c in self.collective_ops())
