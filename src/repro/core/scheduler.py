"""Host-side scheduling state for the multi-tenant wave pipeline.

The wave engine (`engine.WavePipeline`) is a *lane pool*: a persistent
[W, V] device buffer whose rows each peel one schedule cell per fused
step.  Everything the pool needs to know about *which* cell a lane should
peel next is per-query bookkeeping — row cursors, the IntervalSet pruning
state of Rules 1–3, the empty-cell staircase, warm-start rows (Theorem 1)
and TTI dedup (Property 2).  This module owns that bookkeeping:

* :class:`QueryState` — one in-flight TCQ query.  The pipeline calls
  ``claim()`` to draw a ready cell, ``retire()`` to feed back one
  evaluated cell's (TTI, n_edges, packed mask), and ``decode_results()``
  once the query drains.  Because each query keeps its own pruning and
  dedup state, a lane pool serving many QueryStates returns *exactly*
  the result set of running each query alone — cross-query packing only
  changes which lanes cells ride in, never which cores exist.

* :class:`EmptyStaircase` — the incremental replacement for the
  O(|empty_marks|)-per-call ``empty_bound`` scan: empty cell (i, j)
  implies every cell (r >= i, c <= j) is empty, so the bound
  ``max{j : (i, j) marked, i <= r}`` is a monotone step function of r,
  kept as a strictly-increasing corner list with O(log m) queries and
  amortized O(log m) inserts.

* :func:`autotune_wave` — picks the lane count W from the vertex count
  and the *windowed* edge count (each lane costs O(E_w + V) active
  elements per fixpoint iteration), scaled by how many queries the pool
  is serving.
"""

from __future__ import annotations

import bisect
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.intervals import IntervalSet
from repro.core.results import CoreResult, QueryStats


# ---------------------------------------------------------- empty staircase
class EmptyStaircase:
    """Monotone bound ``max{j : mark (i, j), i <= r}`` over empty cells.

    Marks arrive in arbitrary order (wave lanes retire concurrently, rows
    are not swept in ascending order), but the bound itself is
    non-decreasing in r, so only the *dominant* corners need keeping:
    ``_is`` strictly increasing, ``_js`` strictly increasing, and a mark
    (i, j) is dominated iff some kept (i', j') has i' <= i and j' >= j.
    """

    __slots__ = ("_is", "_js")

    def __init__(self):
        self._is: List[int] = []
        self._js: List[int] = []

    def add(self, i: int, j: int) -> None:
        """Record empty cell (i, j); drops it if dominated, else replaces
        every corner it dominates (amortized O(log m))."""
        pos = bisect.bisect_right(self._is, i)
        if pos and self._js[pos - 1] >= j:
            return
        start = pos - 1 if pos and self._is[pos - 1] == i else pos
        end = pos
        while end < len(self._js) and self._js[end] <= j:
            end += 1
        self._is[start:end] = [i]
        self._js[start:end] = [j]

    def bound(self, r: int) -> int:
        """Largest marked j with i <= r, or -1: cells (r, c <= bound) are
        provably empty."""
        pos = bisect.bisect_right(self._is, r)
        return self._js[pos - 1] if pos else -1

    def __len__(self) -> int:
        return len(self._is)


# --------------------------------------------------------------- row cursor
class RowCursor:
    """Cursor of one schedule row: cells (i, j) swept right-to-left."""

    __slots__ = ("i", "j", "first")

    def __init__(self, i: int, n: int):
        self.i, self.j, self.first = i, n - 1, True


# -------------------------------------------------------------- query state
class QueryState:
    """Schedule bookkeeping for one TCQ query served by the lane pool.

    Owns the per-query pruning state (IntervalSets of Rules 1–3, the
    empty-cell staircase), warm-start tracking (best completed row-initial
    core, Theorem 1), TTI dedup (Property 2) and the packed result rows.
    ``stats`` accumulates this query's own counters (cells evaluated,
    prune triggers, duplicates); pipeline-level counters (device steps,
    syncs) belong to whoever runs the pool.
    """

    def __init__(self, uts: np.ndarray, k: int, h: int, prune: bool,
                 stats: QueryStats, qid: int = 0,
                 deadline: float = float("inf"), priority: int = 0,
                 cache=None):
        self.qid = qid
        self.uts = np.asarray(uts)
        self.n = int(self.uts.size)
        self.k, self.h = int(k), int(h)
        self.prune = bool(prune)
        self.stats = stats
        # optional corecache.CacheView bound to this query's (epoch, k, h):
        # claim() resolves cached cells without spending a lane, retire()
        # inserts every freshly peeled cell (insert-on-peel)
        self.cache = cache
        # EDF admission key: the lane pool claims cells from the state
        # with the smallest (deadline, priority) first (scheduler ties
        # fall back to round-robin).  inf deadline = best-effort.
        self.deadline = float(deadline)
        self.priority = int(priority)
        # memoized (deadline, priority): both are fixed at admission, and
        # the pool's EDF claim loop reads the key O(states) per claim
        self._edf = (self.deadline, self.priority)
        # set by cancel(): the pool reclaims this query's lanes at the
        # next assemble/retire instead of peeling them further
        self.cancelled = False
        self.idx_of = {int(t): i for i, t in enumerate(self.uts)}
        self.pruned: Dict[int, IntervalSet] = defaultdict(IntervalSet)
        self.empty = EmptyStaircase()
        # (row, col, device [V] row) of the best completed row-initial core
        self.best_init: Optional[Tuple[int, int, object]] = None
        # cursor objects (not bare indices): cache probing can part-consume
        # a row without claiming a lane, so cursor position must survive
        # being requeued
        self.pending = deque(RowCursor(i, self.n) for i in range(self.n))
        self.live_rows = 0          # rows currently holding a lane
        # tti key -> (packed uint32 row, n_edges); decoded in bulk at the end
        self.collected: Dict[Tuple[int, int], Tuple[np.ndarray, int]] = {}

    # ------------------------------------------------------------- claiming
    @property
    def drained(self) -> bool:
        """No more rows to hand out (in-flight rows may still be peeling)."""
        return not self.pending

    @property
    def done(self) -> bool:
        return not self.pending and self.live_rows == 0

    def cancel(self) -> None:
        """Withdraw the query: drop every unclaimed cell and flag the
        state so the lane pool frees its in-flight lanes (deadline
        timeout / client cancellation).  Idempotent; ``done`` becomes
        True once the pool has reclaimed the last live lane."""
        self.cancelled = True
        self.pending.clear()

    def claim(self) -> Optional[RowCursor]:
        """Next ready row cursor, or None when nothing is pending.

        With a cache attached, cells that resolve from it are consumed
        here — fed through the same pruning/dedup feedback as a peeled
        cell — and only a row whose next cell *misses* ever takes a lane.
        """
        while self.pending:
            row = self.pending.popleft()
            if not self._advance(row):
                continue
            if self._drain_cached(row):
                self.live_rows += 1
                return row
        return None

    def _drain_cached(self, row: RowCursor) -> bool:
        """Resolve the row's cells from the cache until a miss (True — the
        row still needs a lane) or exhaustion (False)."""
        if self.cache is None:
            return True
        while True:
            hit = self.cache.lookup(*self.window(row))
            if hit is None:
                return True
            self.stats.cells_cached += 1
            if not self._feedback(row, hit.tti_lo, hit.tti_hi, hit.n_edges,
                                  hit.packed, None):
                return False

    def resolve_cached(self) -> int:
        """Admission-time sweep: resolve every pending row as far as the
        cache reaches; rows that miss keep their cursor position for the
        lane pool.  Returns the number of cells resolved (``done`` turns
        True when the whole query was served from cache)."""
        resolved0 = self.stats.cells_cached
        if self.cache is not None and not self.cancelled:
            keep = deque()
            while self.pending:
                row = self.pending.popleft()
                if self._advance(row) and self._drain_cached(row):
                    keep.append(row)
            self.pending = keep
        return self.stats.cells_cached - resolved0

    def _advance(self, row: RowCursor) -> bool:
        """Move the cursor past pruned/empty cells; False once exhausted."""
        j = self.pruned[row.i].highest_uncovered_leq(row.j)
        if j is None or j < row.i or j <= self.empty.bound(row.i):
            return False
        row.j = j
        return True

    def window(self, row: RowCursor) -> Tuple[int, int]:
        return int(self.uts[row.i]), int(self.uts[row.j])

    def warm_start(self, row: RowCursor):
        """Device [V] row to warm the lane with, or None for cold all-ones.

        Theorem 1: any completed core over an enclosing window is a valid
        peel superset, so the widest finished row-initial core warms every
        cell it sandwiches."""
        b = self.best_init
        if b is not None and b[0] <= row.i and b[1] >= row.j:
            return b[2]
        return None

    # ------------------------------------------------------------- retiring
    def retire(self, row: RowCursor, tti_lo: int, tti_hi: int, n_edges: int,
               packed_row: np.ndarray, alive_row: Callable[[], object]
               ) -> bool:
        """Feed back one evaluated cell; True iff the row keeps its lane
        (its peeled mask is then the warm start for the next cell).

        ``alive_row`` is a thunk producing the lane's device [V] row — it
        is only materialized when the cell becomes the new best warm-start
        row, so retiring never copies lanes it does not need.

        With a cache attached, the peeled cell is inserted before feedback
        (insert-on-peel), and the row's subsequent cells are drained from
        the cache so the lane is only kept for a genuine miss.
        """
        if self.cache is not None:
            ts, te = self.window(row)
            if n_edges == 0:
                self.cache.insert_empty(ts, te)
            else:
                self.cache.insert(ts, te, tti_lo, tti_hi, n_edges,
                                  packed_row)
        keep = self._feedback(row, tti_lo, tti_hi, n_edges, packed_row,
                              alive_row)
        if keep:
            keep = self._drain_cached(row)
        if not keep:
            self.live_rows -= 1
        return keep

    def _feedback(self, row: RowCursor, tti_lo: int, tti_hi: int,
                  n_edges: int, packed_row: Optional[np.ndarray],
                  alive_row: Optional[Callable[[], object]]) -> bool:
        """Apply one resolved cell (peeled or cache-served) to the query's
        pruning/dedup/staircase state and advance the cursor; True while
        the row has cells left.  ``alive_row`` is None for cache hits —
        there is no device row to promote to a warm start (Theorem 1 makes
        that a pure perf concession, never a correctness one)."""
        i, j = row.i, row.j
        stats = self.stats
        if n_edges == 0:
            self.empty.add(i, j)        # staircase: row exhausted
            return False
        a_idx = self.idx_of[tti_lo]
        b_idx = self.idx_of[tti_hi]
        key = (tti_lo, tti_hi)
        if key in self.collected:
            stats.duplicates += 1
        else:
            self.collected[key] = (packed_row, n_edges)
        if alive_row is not None and row.first and \
                (self.best_init is None or j >= self.best_init[1]):
            self.best_init = (i, j, alive_row())
        row.first = False
        if self.prune:
            if b_idx < j:                        # Rule 1: PoR
                stats.por_triggers += 1
                stats.pruned_por += self.pruned[i].add(b_idx, j - 1)
            if a_idx > i:                        # Rule 2: PoU
                stats.pou_triggers += 1
                for r2 in range(i + 1, a_idx + 1):
                    stats.pruned_pou += self.pruned[r2].add(r2, j)
            if a_idx > i and b_idx < j:          # Rule 3: PoL
                stats.pol_triggers += 1
                for r2 in range(a_idx + 1, b_idx + 1):
                    stats.pruned_pol += self.pruned[r2].add(b_idx + 1, j)
            row.j = (b_idx - 1) if b_idx < j else j - 1
        else:
            row.j = j - 1
        return self._advance(row)

    # -------------------------------------------------------------- results
    def decode_results(self, num_vertices: int
                       ) -> Dict[Tuple[int, int], CoreResult]:
        """One deferred bulk unpack of every collected packed core row.

        Rows are grouped by packed width before stacking: cache-served
        rows may predate a capacity growth and carry fewer uint32 words
        than freshly peeled ones.  Vertex capacities only ever grow and
        padded vertices are never core members, so a narrower row decodes
        to the same vertex set.
        """
        from repro.core.engine import unpack_alive_u32

        results: Dict[Tuple[int, int], CoreResult] = {}
        by_width: Dict[int, list] = defaultdict(list)
        for key, (packed_row, _) in self.collected.items():
            by_width[int(packed_row.size)].append(key)
        for width, keys in by_width.items():
            bits = unpack_alive_u32(
                np.stack([self.collected[key][0] for key in keys]),
                min(int(num_vertices), width * 32))
            # one nonzero over the stacked group, split at row boundaries
            # (vs a flatnonzero per core: this loop is the hot tail of
            # every query's finalize)
            rows_idx, cols = np.nonzero(bits)
            verts = np.split(cols, np.searchsorted(
                rows_idx, np.arange(1, len(keys))))
            for key, v in zip(keys, verts):
                results[key] = CoreResult(
                    k=self.k, tti=key, vertices=v,
                    n_edges=self.collected[key][1])
        return results


# ----------------------------------------------------------- lane autotuning
_LANE_ELEM_BUDGET = 1 << 19     # active elements (~f32 words) per device step
_LANES_PER_QUERY = 8            # demand: lanes one query can keep busy
_W_MIN, _W_MAX = 4, 64


def autotune_wave(num_vertices: int, window_edges: int,
                  num_queries: int = 1, depth: int = 2,
                  lane_shards: int = 1) -> int:
    """Pick the lane count W for a (batch of) wave queries.

    One fixpoint iteration touches O(W * (E_w + V)) active elements (edge
    activity + degrees per lane), so W is sized to keep the pipeline's
    *in-flight* working set near ``_LANE_ELEM_BUDGET`` — large enough to
    amortize per-step dispatch/sync overhead, small enough to stay
    cache/VMEM-resident and to bound the waste of the shared fixpoint loop
    (every lane runs until the slowest converges).  The slot ring keeps
    ``depth`` lane buffers in flight at once (D·W lanes of live state),
    so the supply bound scales as 1/depth — the budget is calibrated at
    the default depth of 2, and deeper rings shrink W instead of
    overshooting the element budget.  Demand caps supply: a single query
    rarely keeps more than ~8 lanes full (schedule tails drain), so W
    also scales with how many queries the pool serves.  Result is a power
    of two in [4, 64] so lane-buffer shapes (and compiled programs) are
    reused.

    On a mesh, ``lane_shards`` is the lane-axis size (pod x data): the
    supply/budget math is *per shard* (each shard holds W/L lanes of
    live state and the edge shards are narrower by the model factor,
    which ``window_edges`` callers already account for by passing the
    union-window edge count — conservative), the per-query demand is
    divided across shards, and the result is scaled back to a global W
    that is a multiple of L so the [W, V] buffer splits evenly over the
    lane axis.  ``lane_shards=1`` reproduces the single-device choice
    exactly.
    """
    per_lane = max(1, int(num_vertices) + int(window_edges))
    supply = max(1, (2 * _LANE_ELEM_BUDGET) // (per_lane * max(1, int(depth))))
    shards = max(1, int(lane_shards))
    demand = -(-(_LANES_PER_QUERY * max(1, int(num_queries))) // shards)
    w = max(_W_MIN, min(_W_MAX, supply, demand))
    w = 1 << (w.bit_length() - 1)               # round down to a power of two
    return w * shards


# Dense psum payloads up to this many elements (V * W f32 degrees) are
# cheaper than the extra all-gather latency of rs_ag on small problems;
# beyond it the ~7x wire saving of reduce-scatter + 1-byte alive gather
# wins.  See combine_bytes_per_lane_iter in core/distributed.py for the
# analytic model that stats() reports alongside the choice.
_COMBINE_DENSE_MAX = 1 << 16


def choose_combine(num_vertices: int, wave: int, model_shards: int) -> str:
    """Auto-select the sharded degree-combine collective: dense all-reduce
    ("psum") for small V*W payloads, reduce-scatter + alive all-gather
    ("rs_ag") once the dense payload outgrows ``_COMBINE_DENSE_MAX``.
    Single-model-shard meshes have no combine; "psum" (a no-op) keeps the
    compiled program collective-free."""
    if model_shards <= 1:
        return "psum"
    if int(num_vertices) * max(1, int(wave)) <= _COMBINE_DENSE_MAX:
        return "psum"
    return "rs_ag"
