"""Per-architecture smoke tests (reduced configs, CPU).

For each assigned architecture: one forward + one train step (loss, grads,
SGD update) asserting shapes and finiteness; prefill+decode consistency
against the full forward (exercises every cache type: attention KV, Mamba
ssm+conv, RWKV wkv+shifts, whisper cross-KV); analytic param-count vs the
real parameter tree (drives roofline MODEL_FLOPS).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import transformer as T

ARCHS = list_archs()


def _batch(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.input_mode == "embeds":
        batch["embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (b, s, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    if cfg.encoder_layers:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (b, s, cfg.d_model)), jnp.float32)
    if cfg.pos == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, s))
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                  jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, 0)
    batch = _batch(cfg, 2, 32)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: T.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert jnp.isfinite(loss), arch
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0, arch
    new_params = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    loss2, _ = T.loss_fn(cfg, new_params, batch)
    assert jnp.isfinite(loss2), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # exact prefill==train equivalence needs drop-free routing: capacity
        # cutoffs depend on the total token count, which differs by design
        import dataclasses
        cfg = cfg.scaled(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    params = T.init_params(cfg, 0)
    b, s = 2, 17
    batch = _batch(cfg, b, s, seed=1)
    # reference: full forward
    hid_ref, _, _ = T.forward(cfg, params, batch, mode="train")
    # prefill on the first s-1 tokens
    s_max = s + 3
    cache = T.init_cache(cfg, b, s_max, s_enc=s if cfg.encoder_layers else None)
    pre = {k: (v[:, :, : s - 1] if k == "positions" and v.ndim == 3
               else v[:, : s - 1] if k in ("tokens", "labels")
               else v[:, : s - 1] if k == "embeds" else v)
           for k, v in batch.items()}
    pre.pop("labels")
    hid_pre, _, cache = T.forward(cfg, params, pre, mode="prefill",
                                  cache=cache)
    np.testing.assert_allclose(np.asarray(hid_pre),
                               np.asarray(hid_ref[:, : s - 1]),
                               rtol=2e-3, atol=2e-3)
    # decode the final token
    dec = {}
    if cfg.input_mode == "embeds":
        dec["embeds"] = batch["embeds"][:, s - 1: s]
    else:
        dec["tokens"] = batch["tokens"][:, s - 1: s]
    if cfg.pos == "mrope":
        dec["positions"] = batch["positions"][:, :, s - 1: s]
    else:
        dec["positions"] = jnp.full((b, 1), s - 1, jnp.int32)
    dec["cache_index"] = jnp.asarray(s - 1, jnp.int32)
    hid_dec, _, cache2 = T.forward(cfg, params, dec, mode="decode",
                                   cache=cache)
    np.testing.assert_allclose(np.asarray(hid_dec[:, 0]),
                               np.asarray(hid_ref[:, s - 1]),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_template(arch):
    cfg = get_config(arch)  # FULL config, abstract tree only
    tree = T.abstract_params(cfg)
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
    analytic = cfg.param_count()
    assert abs(actual - analytic) / analytic < 0.03, (
        arch, actual, analytic)


@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b",
                                  "llama4-scout-17b-a16e", "qwen2-vl-72b"])
def test_full_config_scale(arch):
    """Headline parameter counts land near the published sizes."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {"jamba-1.5-large-398b": 398e9,
                "llama4-scout-17b-a16e": 108e9,  # total (17B active)
                "qwen2-vl-72b": 72e9}[arch]
    assert abs(n - expected) / expected < 0.12, (arch, n, expected)
    assert cfg.active_param_count() <= n


def test_moe_capacity_drop():
    cfg = get_smoke_config("granite-moe-1b-a400m")
    import dataclasses

    cfg = cfg.scaled(moe=dataclasses.replace(cfg.moe, capacity_factor=0.5))
    params = T.init_params(cfg, 0)
    h, aux, _ = T.forward(cfg, params, _batch(cfg, 2, 32), mode="train")
    assert bool(jnp.isfinite(h).all())


def test_gemma2_softcap_and_windows():
    cfg = get_smoke_config("gemma2-2b")
    assert cfg.attn_softcap and cfg.logit_softcap
    specs = cfg.layer_specs()
    assert specs[0].window is not None and specs[1].window is None
    params = T.init_params(cfg, 0)
    h, _, _ = T.forward(cfg, params, _batch(cfg, 1, 40), mode="train")
    assert bool(jnp.isfinite(h).all())


def test_rwkv_chunked_equals_stepwise():
    """The chunked WKV evaluation equals the exact token-by-token recurrence."""
    from repro.models.rwkv import rwkv_time_mix

    cfg = get_smoke_config("rwkv6-1.6b")
    params = T.init_params(cfg, 3)
    p = jax.tree.map(lambda x: x[0], params["dec"]["sub0"]["mixer"])
    b, s, d = 2, 23, cfg.d_model
    x = jnp.asarray(np.random.default_rng(0).normal(0, 0.5, (b, s, d)),
                    jnp.float32)
    h = d // cfg.rwkv.head_dim
    st0 = (jnp.zeros((b, h, cfg.rwkv.head_dim, cfg.rwkv.head_dim)),
           jnp.zeros((b, d)))
    y_chunk, (s_chunk, _) = rwkv_time_mix(p, x, cfg, st0, chunk=8)
    y_step, (s_step, _) = rwkv_time_mix(p, x, cfg, st0, chunk=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(s_step),
                               rtol=2e-4, atol=2e-4)


def test_mamba_chunked_equals_stepwise():
    from repro.models.ssm import mamba_mix

    cfg = get_smoke_config("jamba-1.5-large-398b")
    params = T.init_params(cfg, 4)
    p = jax.tree.map(lambda x: x[0], params["dec"]["sub0"]["mixer"])
    b, s, d = 2, 19, cfg.d_model
    m = cfg.mamba
    x = jnp.asarray(np.random.default_rng(1).normal(0, 0.5, (b, s, d)),
                    jnp.float32)
    st0 = (jnp.zeros((b, m.d_inner(d), m.d_state)),
           jnp.zeros((b, m.d_conv - 1, m.d_inner(d))))
    y_big, (s_big, _) = mamba_mix(p, x, cfg, st0, chunk=64)
    y_small, (s_small, _) = mamba_mix(p, x, cfg, st0, chunk=1)
    np.testing.assert_allclose(np.asarray(y_big), np.asarray(y_small),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_big), np.asarray(s_small),
                               rtol=2e-4, atol=2e-4)


def test_chunked_attention_equals_dense():
    from repro.models.attention import (_attend_chunked, _attend_dense,
                                        _mask_bias)

    rng = np.random.default_rng(2)
    b, s, h, kv, hd = 2, 50, 4, 2, 16
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, kv, hd)), jnp.float32)
    rank = jnp.arange(s, dtype=jnp.int32)[None]  # batch-free sequence ranks
    for window, cap in [(None, None), (8, None), (None, 30.0)]:
        bias = _mask_bias(rank, rank, True, window)
        dense = _attend_dense(q, k, v, bias, hd ** -0.5, cap)
        chunked = _attend_chunked(q, k, v, rank, rank, True, window,
                                  hd ** -0.5, cap, chunk=16)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                                   rtol=2e-5, atol=2e-5)
