"""Perf-hillclimb driver: lower one cell with ModelConfig overrides and log
the roofline delta vs a named baseline record.

    PYTHONPATH=src python -m benchmarks.perf_lower \
        --arch jamba-1.5-large-398b --shape train_4k \
        --set mamba_scan=assoc --tag jamba_assoc

``--wave-step`` instead audits the fused wave-peel kernel lowering: it
lowers the unfused XLA peel chain, censuses its [W, E] HBM
materializations, and ASSERTS the fused lowering eliminates them (its
only HBM operands are the [1, E] tables and the [W, V] lane slab;
per-iteration HBM bytes are zero by construction).

    PYTHONPATH=src python -m benchmarks.perf_lower --wave-step
"""

import argparse
import ast
import json
import os
import sys


def wave_step_mode(args) -> None:
    from benchmarks.bench_wave import analyze_fused_step

    info = analyze_fused_step(args.graph, wave=args.wave)
    print(f"[wave-step] graph={info['graph']} W={info['wave']} "
          f"E={info['num_edges']} iters={info['iters']} "
          f"backend={info['backend']}"
          f"{' (interpret)' if info['interpret'] else ''}")
    print(f"  unfused: {info['unfused_bytes_step']:.3e} B/step "
          f"({info['unfused_bytes_per_iter']:.3e} B/iter), "
          f"[W,E] HBM materializations per iter: "
          f"{info['unfused_we_materializations']}")
    print(f"  fused:   {info['fused_bytes_step']:.3e} B/step "
          f"({info['fused_bytes_per_iter_hbm']:.0f} B/iter HBM), "
          f"[W,E] HBM materializations: "
          f"{info['fused_we_materializations']}")
    print(f"  bytes ratio fused/unfused: {info['bytes_ratio']:.2e}")
    if info["unfused_we_materializations"] <= 0:
        sys.exit("[wave-step] FAIL: no [W, E] materializations found in "
                 "the unfused lowering — baseline census is broken")
    if info["fused_we_materializations"] != 0:
        sys.exit("[wave-step] FAIL: fused lowering still round-trips "
                 "[W, E] arrays through HBM")
    if not info["fused_bytes_step"] < info["unfused_bytes_step"]:
        sys.exit("[wave-step] FAIL: fused lowering does not reduce HBM "
                 "bytes per step")
    print("[wave-step] OK: fused lowering eliminates the [W, E] HBM "
          "round-trips")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--set", action="append", default=[],
                    help="field=value ModelConfig override (repeatable)")
    ap.add_argument("--tag")
    ap.add_argument("--baseline", default="",
                    help="path of a baseline record to diff against")
    ap.add_argument("--wave-step", action="store_true",
                    help="audit the fused wave-peel kernel lowering "
                         "instead of lowering a dry-run cell")
    ap.add_argument("--graph", default="collegemsg",
                    help="benchmark graph for --wave-step")
    ap.add_argument("--wave", type=int, default=16,
                    help="lane count for --wave-step")
    args = ap.parse_args()

    if args.wave_step:
        wave_step_mode(args)
        return
    if not (args.arch and args.shape and args.tag):
        ap.error("--arch, --shape and --tag are required "
                 "(unless --wave-step)")

    from repro.launch.dryrun import lower_cell

    overrides = {}
    for kv in getattr(args, "set"):
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v
    rec, _ = lower_cell(args.arch, args.shape, args.mesh == "multi",
                        overrides=overrides)
    rec["overrides"] = overrides
    out = os.path.join(os.path.dirname(__file__), "results", "perf",
                       args.tag + ".json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    rl = rec["roofline"]
    print(f"[perf] {args.tag}: t_comp={rl['t_compute_s']:.3f} "
          f"t_mem={rl['t_memory_s']:.3f} t_coll={rl['t_collective_s']:.3f} "
          f"dom={rl['dominant']} frac={rl.get('roofline_fraction', 0):.5f}")
    if args.baseline and os.path.exists(args.baseline):
        base = json.load(open(args.baseline))["roofline"]
        for k in ("t_compute_s", "t_memory_s", "t_collective_s",
                  "roofline_fraction"):
            if base.get(k):
                print(f"  {k:18s} {base[k]:10.4f} -> {rl[k]:10.4f} "
                      f"({rl[k] / base[k]:.3f}x)")


if __name__ == "__main__":
    main()
