"""Wave-native batched TCD: Q query cells peeled in lockstep, kernel-ready.

`tcd_batch` (tcd.py) vmaps the scalar path; this module lays the data out
the way the MXU wants it — values [E, Q] / [2P, Q] — so the two segment
reductions become banded one-hot matmuls (the Pallas kernel), and the whole
wave shares one fixpoint loop.  The edge-activity / degree split lets
callers (engine.py's fused ``wave_step``) carry edge activity through the
fixpoint loop and skip the post-loop edge pass.  This is also the
single-shard block of the distributed engine (distributed.py wraps it in
shard_map with a cross-shard degree combine).
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.graph import DeviceTEL, TemporalGraph

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min


class WaveResult(NamedTuple):
    alive: jnp.ndarray    # [Q, V]
    tti_lo: jnp.ndarray   # [Q]
    tti_hi: jnp.ndarray   # [Q]
    n_edges: jnp.ndarray  # [Q]
    n_verts: jnp.ndarray  # [Q]
    iters: jnp.ndarray    # scalar: fixpoint iterations of the wave


def make_segsum_fns(graph: TemporalGraph, *, use_kernel: Optional[bool] = None,
                    interpret: Optional[bool] = None):
    """(edges->pairs, halfpairs->vertices) segment-sum closures for a graph.

    use_kernel=True routes through the Pallas banded kernel (interpret mode
    on CPU); False uses jax.ops.segment_sum (XLA scatter path); None (the
    default) auto-dispatches — compiled Pallas on TPU, XLA elsewhere.  The
    band analysis (k_max) runs here, once per graph/engine.
    """
    from repro.kernels.segdeg.ops import make_banded_segsum, on_tpu

    if use_kernel is None:
        use_kernel = on_tpu()
    tel_hp_src = np.sort(np.concatenate([graph.pair_u, graph.pair_v]))
    seg_pair = make_banded_segsum(graph.pair_id, graph.num_pairs,
                                  use_kernel=use_kernel, interpret=interpret)
    seg_vert = make_banded_segsum(tel_hp_src, graph.num_vertices,
                                  use_kernel=use_kernel, interpret=interpret)
    return seg_pair, seg_vert


def wave_edge_activity(tel: DeviceTEL, alive: jnp.ndarray, ts, te
                       ) -> jnp.ndarray:
    """alive: [Q, V]; ts/te: [Q].  Returns [Q, E] bool edge activity."""
    win = (tel.t[None, :] >= ts[:, None]) & (tel.t[None, :] <= te[:, None])
    return win & alive[:, tel.src] & alive[:, tel.dst]


def wave_degrees_from_ea(tel: DeviceTEL, ea: jnp.ndarray, h,
                         *, num_vertices: int, seg_pair: Callable,
                         seg_vert: Callable) -> jnp.ndarray:
    """ea: [Q, E] edge activity; h: scalar or per-lane [Q].
    Returns [Q, V] int32 degrees."""
    paircnt = seg_pair(ea.T.astype(jnp.float32), tel.pair_id)  # [P, Q]
    pairact = (paircnt >= h).astype(jnp.float32)   # h broadcasts over lanes
    contrib = pairact[tel.hp_pair, :]                          # [2P, Q]
    deg = seg_vert(contrib, tel.hp_src)                        # [V, Q]
    return deg.T.astype(jnp.int32)


def wave_degrees(tel: DeviceTEL, alive: jnp.ndarray, ts, te, h,
                 *, num_vertices: int, seg_pair: Callable, seg_vert: Callable
                 ) -> jnp.ndarray:
    """alive: [Q, V]; ts/te: [Q].  Returns [Q, V] int32 degrees."""
    ea = wave_edge_activity(tel, alive, ts, te)
    return wave_degrees_from_ea(tel, ea, h, num_vertices=num_vertices,
                                seg_pair=seg_pair, seg_vert=seg_vert)


def peel_to_fixpoint(tel: DeviceTEL, alive: jnp.ndarray, ts, te, k, h,
                     *, num_vertices: int, seg_pair, seg_vert,
                     max_iters: int = 0):
    """Shared batched peel loop -> (alive, ea, iters); trace-time building
    block for `tcd_wave` and engine.wave_step.

    k and h may be scalars (one threshold for the whole wave) or per-lane
    [Q] vectors — the multi-tenant scheduler packs cells from queries with
    different (k, h) into one wave, so the survivor test broadcasts the
    thresholds per lane.

    ea rides in the carry (as in tcd.tcd): the final iteration observed
    new == cur, so the carried ea is exactly the fixpoint's edge activity
    and callers skip the post-loop edge pass.
    """
    q = alive.shape[0]
    k_lane = jnp.broadcast_to(jnp.asarray(k, jnp.int32), (q,))
    h_lane = jnp.broadcast_to(jnp.asarray(h, jnp.int32), (q,))

    def cond(state):
        _, _, changed, it = state
        more = changed
        if max_iters:
            more = more & (it < max_iters)
        return more

    def body(state):
        cur, _, _, it = state
        ea = wave_edge_activity(tel, cur, ts, te)
        deg = wave_degrees_from_ea(tel, ea, h_lane,
                                   num_vertices=num_vertices,
                                   seg_pair=seg_pair, seg_vert=seg_vert)
        new = cur & (deg >= k_lane[:, None])
        return new, ea, jnp.any(new != cur), it + 1

    ea0 = jnp.zeros((alive.shape[0], tel.t.shape[0]), dtype=bool)
    alive, ea, _, iters = lax.while_loop(
        cond, body, (alive, ea0, jnp.bool_(True), jnp.int32(0)))
    if max_iters:  # truncated peel may exit pre-fixpoint: ea would be stale
        ea = wave_edge_activity(tel, alive, ts, te)
    return alive, ea, iters


@functools.partial(jax.jit, static_argnames=("num_vertices", "seg_pair",
                                             "seg_vert", "max_iters"))
def tcd_wave(tel: DeviceTEL, alive: jnp.ndarray, ts, te, k, h,
             *, num_vertices: int, seg_pair, seg_vert,
             max_iters: int = 0) -> WaveResult:
    """Batched TCD to the fixpoint.  alive: [Q, V] warm-start supersets;
    k/h: scalars or per-lane [Q] vectors (mixed-threshold waves)."""
    alive, ea, iters = peel_to_fixpoint(
        tel, alive, ts, te, k, h, num_vertices=num_vertices,
        seg_pair=seg_pair, seg_vert=seg_vert, max_iters=max_iters)
    n_edges = jnp.sum(ea, axis=1, dtype=jnp.int32)
    tti_lo = jnp.min(jnp.where(ea, tel.t[None, :], _I32_MAX), axis=1)
    tti_hi = jnp.max(jnp.where(ea, tel.t[None, :], _I32_MIN), axis=1)
    n_verts = jnp.sum(alive, axis=1, dtype=jnp.int32)
    return WaveResult(alive, tti_lo, tti_hi, n_edges, n_verts, iters)
