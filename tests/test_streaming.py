"""Streaming runtime equivalence gates.

The three load-bearing properties of the service refactor:

1. **Incremental append == rebuild** — ``TemporalGraph.add_edges``'s
   sorted-run merge must produce *bit-identical* canonical arrays to a
   from-scratch ``from_edges`` rebuild (same pair factorization, same
   canonical order, same dtypes), across arbitrary batch sequences:
   late timestamps, new vertices, new pairs, duplicate edges.

2. **Mid-flight admission == isolation** — a query admitted into a live
   pool while other queries are peeling returns exactly the result of
   running it alone on its pinned snapshot.

3. **Epoch pinning** — no query ever observes edges pushed after its
   admission, and post-push queries observe exactly the new snapshot.

Plus: an ``EmptyStaircase`` fuzz against the naive empty-marks scan, the
depth-aware ``autotune_wave`` budget, capacity-class shape stability
under appends, and window clustering.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: vendored seeded fallback
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (TCQEngine, TCQService, TemporalGraph,
                        cluster_windows)
from repro.core.scheduler import EmptyStaircase, autotune_wave

CANON_FIELDS = ("src", "dst", "t", "pair_id", "pair_u", "pair_v",
                "unique_ts")


def assert_graphs_identical(got, want):
    for f in CANON_FIELDS:
        a, b = getattr(got, f), getattr(want, f)
        assert a.dtype == b.dtype, f
        assert np.array_equal(a, b), f
    assert got.num_vertices == want.num_vertices


def assert_same(got, want, ctx=""):
    assert got.by_tti().keys() == want.by_tti().keys(), ctx
    for key, cw in want.by_tti().items():
        cg = got.by_tti()[key]
        assert np.array_equal(cg.vertices, cw.vertices), (ctx, key)
        assert cg.n_edges == cw.n_edges, (ctx, key)


def random_graph(seed, n_v=20, n_e=140, max_t=16):
    rng = np.random.default_rng(seed)
    return TemporalGraph.from_edges(rng.integers(0, n_v, n_e),
                                    rng.integers(0, n_v, n_e),
                                    rng.integers(1, max_t + 1, n_e), n_v)


# ------------------------------------------------- append == rebuild (exact)
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_merge_append_bit_identical_to_rebuild(seed):
    rng = np.random.default_rng(seed)
    n_v = int(rng.integers(3, 30))
    batches = []
    for bi in range(int(rng.integers(2, 6))):
        b = int(rng.integers(0, 50))
        # batches 1+ may introduce new vertices (n_v grows) and late
        # (out-of-order, negative) timestamps
        hi_v = n_v + (bi * 7 if bi else 0)
        batches.append((rng.integers(0, hi_v, b), rng.integers(0, hi_v, b),
                        rng.integers(-25, 25, b)))
    g = TemporalGraph.from_edges(*batches[0])
    flat = [np.asarray(c) for c in batches[0]]
    for bi, (u, v, t) in enumerate(batches[1:], start=1):
        g = g.add_edges(u, v, t)
        assert g.epoch == bi
        flat = [np.concatenate([a, np.asarray(c)])
                for a, c in zip(flat, (u, v, t))]
    ref = TemporalGraph.from_edges(*flat, num_vertices=g.num_vertices)
    assert_graphs_identical(g, ref)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.lists(st.tuples(st.integers(0, 14), st.integers(0, 14),
                                   st.integers(-9, 9)),
                         min_size=0, max_size=20),
                min_size=1, max_size=5))
def test_merge_append_fuzz(batches):
    """Hypothesis fuzz: any batch sequence (duplicates, self loops, empty
    batches, late data) merges to the exact rebuilt canonical arrays."""
    def cols(b):
        if not b:
            return (np.zeros(0, np.int64),) * 3
        a = np.asarray(b, np.int64)
        return a[:, 0], a[:, 1], a[:, 2]

    g = TemporalGraph.from_edges(*cols(batches[0]), num_vertices=15)
    flat = list(batches[0])
    for b in batches[1:]:
        g = g.add_edges(*cols(b))
        flat += list(b)
    ref = TemporalGraph.from_edge_list(flat, num_vertices=g.num_vertices)
    assert_graphs_identical(g, ref)


def test_append_empty_and_self_loop_batches_are_noops():
    g = random_graph(5)
    assert g.add_edges([], [], []) is g
    assert g.add_edges([3, 7], [3, 7], [1, 2]) is g
    assert g.epoch == 0


# ------------------------------------------------------- engine epoch swaps
def test_update_graph_equals_fresh_engine():
    g0 = random_graph(7, n_v=18, n_e=120, max_t=14)
    eng = TCQEngine(g0)
    Ts, Te = g0.span
    base = eng.query(2, Ts, Te)
    rng = np.random.default_rng(8)
    g1 = g0.add_edges(rng.integers(0, 22, 40), rng.integers(0, 22, 40),
                      rng.integers(1, 20, 40))
    assert eng.update_graph(g1) == eng.epoch == 1
    for mode in ("serial", "wave"):
        got = eng.query(2, *g1.span, mode=mode)
        want = TCQEngine(g1).query(2, *g1.span)
        assert_same(got, want, ctx=mode)
    # the pre-update result is reproducible from the old snapshot
    assert_same(base, TCQEngine(g0).query(2, Ts, Te))


def test_update_graph_capacity_classes_keep_shapes():
    """Appends inside a capacity class must not change device TEL shapes
    (that is what lets streaming reuse compiled programs)."""
    g = random_graph(9, n_v=30, n_e=100, max_t=20)
    eng = TCQEngine(g)
    # first growth jumps the edge buffers to a power-of-two capacity
    g = g.add_edges([1, 2, 3], [4, 5, 6], [3, 4, 5])
    eng.update_graph(g)
    shape0 = {f: getattr(eng.tel, f).shape for f in eng.tel._fields}
    cap0 = (eng._edge_cap, eng._pair_cap, eng._v_cap)
    assert eng._edge_cap == 128      # pow2 bucket above 103
    rng = np.random.default_rng(10)
    while g.num_edges < cap0[0] and g.num_pairs < cap0[1]:
        g = g.add_edges(rng.integers(0, 30, 4), rng.integers(0, 30, 4),
                        rng.integers(1, 24, 4))
        eng.update_graph(g)
        if (eng._edge_cap, eng._pair_cap, eng._v_cap) != cap0:
            break               # a class legitimately grew: shapes may too
        assert {f: getattr(eng.tel, f).shape
                for f in eng.tel._fields} == shape0
    # growth beyond the class doubles it (power-of-two)
    add = cap0[0]
    g = g.add_edges(rng.integers(0, 30, add), rng.integers(0, 30, add),
                    rng.integers(1, 24, add))
    eng.update_graph(g)
    assert eng._edge_cap >= 2 * cap0[0]
    assert eng._edge_cap & (eng._edge_cap - 1) == 0


def test_window_cache_is_epoch_keyed():
    g0 = random_graph(11, n_v=16, n_e=110, max_t=18)
    eng = TCQEngine(g0)
    Ts, Te = g0.span
    lo, hi = Ts + 2, Te - 2
    r0 = eng.query(2, lo, hi)
    assert (0, lo, hi) in eng._win_cache
    # push edges INSIDE the window: a stale truncation would be wrong
    g1 = g0.add_edges([0, 1, 2, 3], [5, 6, 7, 8],
                      [lo + 1, lo + 1, lo + 2, lo + 2])
    eng.update_graph(g1)
    r1 = eng.query(2, lo, hi)
    assert (1, lo, hi) in eng._win_cache      # new epoch, new entry
    want = TCQEngine(g1).query(2, lo, hi)
    assert_same(r1, want)
    # and the old snapshot's result is still derivable from its epoch
    assert_same(r0, TCQEngine(g0).query(2, lo, hi))


# ------------------------------------------------------ service: mid-flight
@pytest.mark.parametrize("seed", [0, 4])
def test_midflight_admission_equals_isolated(seed):
    g = random_graph(seed, n_v=22, n_e=200, max_t=20)
    Ts, Te = g.span
    mid = (Ts + Te) // 2
    svc = TCQService(g, wave=4)
    first = svc.submit({"k": 2, "ts": Ts, "te": Te})
    late_reqs = [{"k": 3, "ts": Ts, "te": mid},
                 {"k": 2, "ts": mid, "te": Te, "h": 2},
                 {"k": 4, "ts": Ts + 1, "te": Te - 1}]
    injected = []

    def poll(s):
        if late_reqs:
            injected.append(s.submit(late_reqs.pop()))

    served = svc.run_until_idle(poll)
    assert first.done and all(tk.done for tk in injected)
    assert len(served) == 4
    # at least some of the injected queries joined the live pool
    assert sum(p["admitted_midflight"] for p in svc.pool_log) >= 1
    eng = TCQEngine(g)
    for tk in [first] + injected:
        want = eng.query(tk.k, tk.ts, tk.te, h=tk.h)
        assert_same(tk.result, want, ctx=f"ticket {tk.id}")


def test_epoch_pinning_no_future_edges():
    """A query admitted at epoch e must not see edges pushed after its
    admission — even when the push lands mid-flight inside its window."""
    g0 = random_graph(13, n_v=20, n_e=160, max_t=18)
    Ts, Te = g0.span
    svc = TCQService(g0, wave=4)
    pinned = svc.submit({"k": 2, "ts": Ts, "te": Te})
    fired = {}

    def poll(s):
        if "late" not in fired:
            # a dense clique inside the pinned window: would change the
            # result set if the pinned query could see it
            u = [0, 0, 0, 1, 1, 2]
            v = [1, 2, 3, 2, 3, 3]
            t = [Ts + 1] * 6
            s.push_edges(u, v, t)
            fired["late"] = s.submit({"k": 2, "ts": Ts, "te": Te})

    svc.run_until_idle(poll)
    late = fired["late"]
    assert pinned.epoch == 0 and late.epoch == 1
    assert_same(pinned.result, TCQEngine(g0).query(2, Ts, Te), "pinned")
    g1 = svc.graph
    assert_same(late.result, TCQEngine(g1).query(2, Ts, Te), "late")
    # the snapshots genuinely diverge (the test would be vacuous otherwise)
    assert len(late.result) != len(pinned.result) or \
        late.result.by_tti().keys() != pinned.result.by_tti().keys()


def test_service_batch_equals_query_batch():
    """Same fixed request set: the clustered service and the single-pool
    query_batch must agree result-for-result."""
    g = random_graph(17, n_v=24, n_e=220, max_t=24)
    Ts, Te = g.span
    third = (Te - Ts) // 3
    reqs = [{"k": 2, "ts": Ts, "te": Ts + third},
            {"k": 3, "ts": Ts, "te": Ts + third // 2},
            {"k": 2, "ts": Te - third, "te": Te},       # disjoint cluster
            {"k": 2, "ts": Te - third // 2, "te": Te, "h": 2}]
    eng = TCQEngine(g)
    batch = eng.query_batch(reqs)
    svc = TCQService(graph=None, engine=eng)
    tickets = [svc.submit(r) for r in reqs]
    svc.run_until_idle()
    assert len(svc.pool_log) == 2       # two window clusters, two pools
    for tk, want in zip(tickets, batch):
        assert_same(tk.result, want, ctx=f"ticket {tk.id}")


def test_empty_window_and_snapshot_retention():
    """Resolved-at-submit tickets must still come back from pump /
    run_until_idle, and completion drops the heavy per-ticket state
    (QueryState always; the pinned snapshot when retain_snapshots=False)."""
    g = random_graph(19)
    Ts, Te = g.span
    svc = TCQService(g)
    empty = svc.submit({"k": 2, "ts": Te + 10, "te": Te + 20})
    real = svc.submit({"k": 2, "ts": Ts, "te": Te})
    served = svc.run_until_idle()
    assert empty in served and real in served
    assert empty.done and len(empty.result) == 0
    assert real.state is None           # packed rows freed on completion
    assert real.graph is g              # snapshots retained by default
    svc2 = TCQService(g, retain_snapshots=False)
    tk = svc2.submit({"k": 2, "ts": Ts, "te": Te})
    out = svc2.run_until_idle()
    assert out == [tk] and tk.done and tk.graph is None


# ------------------------------------------------------------- clustering
def test_cluster_windows():
    assert cluster_windows([]) == []
    assert cluster_windows([(3, 9)]) == [[0]]
    assert cluster_windows([(0, 5), (4, 9), (20, 30), (8, 10)]) == \
        [[0, 1, 3], [2]]
    assert cluster_windows([(10, 12), (0, 2), (3, 5)]) == [[1], [2], [0]]
    assert cluster_windows([(10, 12), (0, 2), (3, 5)], gap=1) == \
        [[1, 2], [0]]
    # chains merge transitively
    assert cluster_windows([(0, 4), (3, 8), (7, 11)]) == [[0, 1, 2]]


# ------------------------------------------------- EmptyStaircase fuzz
@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 24), st.integers(0, 24)),
                min_size=1, max_size=40),
       st.lists(st.integers(-1, 25), min_size=1, max_size=8))
def test_empty_staircase_fuzz_vs_naive(marks, probes):
    stair = EmptyStaircase()
    for i, j in marks:
        stair.add(i, j)
    for r in probes:
        naive = max((je for ie, je in marks if ie <= r), default=-1)
        assert stair.bound(r) == naive, (marks, r)


# ------------------------------------------------------- autotune depth
def test_autotune_wave_accounts_for_ring_depth():
    v, e = 2_000, 60_000
    base = autotune_wave(v, e, num_queries=64, depth=2)
    # the element budget covers D*W lanes in flight: deeper rings shrink W
    assert autotune_wave(v, e, num_queries=64, depth=8) <= base // 2
    # depth=2 matches the historical (pre-depth-aware) tuning
    assert base == autotune_wave(v, e, num_queries=64)
    for depth in (1, 2, 3, 4, 8):
        w = autotune_wave(v, e, num_queries=64, depth=depth)
        assert 4 <= w <= 64 and w & (w - 1) == 0
    # demand-bound regimes (small V*E) are depth-insensitive
    assert autotune_wave(30, 200, num_queries=1, depth=8) == \
        autotune_wave(30, 200, num_queries=1, depth=1)


# ------------------------------------------------ ticket lifecycle edges
def test_cancel_before_first_slot():
    """A ticket cancelled while still queued never touches the pool: it
    resolves immediately with an empty partial result, and its pool-mates
    are served exactly as if it had never been submitted."""
    g = random_graph(21, n_v=22, n_e=200, max_t=20)
    Ts, Te = g.span
    svc = TCQService(g, wave=4)
    keep = svc.submit({"k": 2, "ts": Ts, "te": Te})
    gone = svc.submit({"k": 3, "ts": Ts, "te": Te})
    assert svc.cancel(gone)
    assert gone.status == "cancelled" and gone.done
    assert gone.result is not None and len(gone.result) == 0
    served = svc.run_until_idle()
    assert keep.status == "done"
    # the cancelled ticket was handed back by pump(), not re-run
    assert {tk.id for tk in served} == {keep.id, gone.id}
    assert_same(keep.result, TCQEngine(g).query(2, Ts, Te), "survivor")


def test_deadline_expires_mid_pool():
    """A running ticket whose deadline passes mid-pool has its lanes
    reclaimed at the next wave and resolves as ``timeout`` with whatever
    cells had completed; pool-mates are unaffected."""
    g = random_graph(22, n_v=22, n_e=200, max_t=20)
    Ts, Te = g.span
    svc = TCQService(g, wave=4)
    keep = svc.submit({"k": 2, "ts": Ts, "te": Te})
    # far-future deadline: admitted normally, expired deterministically
    # by the poll below (wall-clock-free determinism)
    doomed = svc.submit({"k": 3, "ts": Ts, "te": Te,
                         "deadline_s": 3600.0})
    state = {"polls": 0}

    def poll(s):
        state["polls"] += 1
        if state["polls"] == 2:         # inside the live pool's admit hook
            doomed.deadline = 1.0       # long past (perf_counter scale)

    svc.run_until_idle(poll)
    assert doomed.status == "timeout" and doomed.done
    assert doomed.result is not None
    assert keep.status == "done"
    assert_same(keep.result, TCQEngine(g).query(2, Ts, Te), "survivor")
    assert any(p["timeouts"] for p in svc.pool_log)


def test_empty_result_query_races_ingest():
    """A query whose window holds no snapshot timestamps resolves empty
    at submit — and stays empty even when an ingest lands edges inside
    that window before the next pump (epoch pinning for the degenerate
    cell-free schedule)."""
    g = random_graph(23, n_v=18, n_e=120, max_t=10)
    Ts, Te = g.span
    svc = TCQService(g, wave=4)
    empty = svc.submit({"k": 2, "ts": Te + 5, "te": Te + 9})
    assert empty.done and empty.status == "done" and len(empty.result) == 0
    # the race: edges land inside [Te+5, Te+9] right after submission
    svc.push_edges([0, 0, 1], [1, 2, 2], [Te + 6, Te + 7, Te + 8])
    fresh = svc.submit({"k": 2, "ts": Te + 5, "te": Te + 9})
    served = svc.run_until_idle()
    assert {tk.id for tk in served} == {empty.id, fresh.id}
    assert len(empty.result) == 0           # still pinned to epoch 0
    assert_same(fresh.result, TCQEngine(svc.graph).query(2, Te + 5, Te + 9),
                "post-ingest")


def test_window_cache_retires_dead_epochs():
    """Window TELs and pair tables of epochs no ticket pins anymore are
    evicted after each pool instead of lingering until LRU capacity."""
    g = random_graph(24, n_v=18, n_e=120, max_t=10)
    Ts, Te = g.span
    svc = TCQService(g, wave=4)
    svc.submit({"k": 2, "ts": Ts, "te": Te})
    svc.push_edges([0, 1], [2, 3], [Ts + 1, Ts + 2])
    svc.submit({"k": 2, "ts": Ts, "te": Te})
    svc.push_edges([2, 3], [4, 5], [Ts + 1, Ts + 2])
    svc.submit({"k": 2, "ts": Ts, "te": Te})
    svc.run_until_idle()
    live = {svc.engine.epoch}
    assert set(svc.engine._epoch_aux) <= live
    assert {key[0] for key in svc.engine._win_cache} <= live
