"""Jitted step builders: train (grad-accum + optimizer), prefill, decode.

All shardings are explicit NamedShardings derived from the config's logical
axes; every builder works on any (data, model) / (pod, data, model) mesh.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as PS

from repro.launch import shapes as S
from repro.models import transformer as T
from repro.optim import make_optimizer


def ns(mesh, pspec_tree):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspec_tree,
                        is_leaf=lambda x: isinstance(x, PS))


def _split_micro(batch, n_micro):
    def split(x):
        if x.ndim == 0:
            return jnp.broadcast_to(x, (n_micro,))
        if x.shape[0] == 3 and x.ndim == 3:  # mrope positions (3,B,S)
            return x.reshape(3, n_micro, -1, *x.shape[2:]).swapaxes(0, 1)
        return x.reshape(n_micro, -1, *x.shape[1:])

    return jax.tree.map(split, batch)


def build_train_step(cfg, mesh, n_micro: int = 1, lr: float = 3e-4):
    """Returns (jitted_step, param_shardings, opt_shardings, batch builder).

    step(params, opt_state, batch) -> (params, opt_state, metrics).
    Gradient accumulation over n_micro microbatches via lax.scan bounds the
    live activation memory of the largest configs.
    """
    from repro.launch.mesh import dp_axes

    opt = make_optimizer(cfg, lr=lr)
    p_spec = T.param_pspecs(cfg, mesh)
    p_ns = ns(mesh, p_spec)
    o_ns = ns(mesh, opt.state_pspecs(p_spec))
    act_ns = NamedSharding(mesh, PS(dp_axes(mesh), None, None))

    def loss_of(params, mb):
        loss, metrics = T.loss_fn(cfg, params, mb, act_sharding=act_ns)
        return loss, metrics

    def step(params, opt_state, batch):
        if n_micro > 1:
            micro = _split_micro(batch, n_micro)

            def acc_fn(carry, mb):
                gsum, lsum = carry
                (loss, _), g = jax.value_and_grad(
                    loss_of, has_aux=True)(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + loss), ()

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_fn, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: (g / n_micro).astype(jnp.float32),
                                 gsum)
            loss = lsum / n_micro
        else:
            (loss, _), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              params, updates)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    metric_ns = {"loss": NamedSharding(mesh, PS()),
                 "grad_norm": NamedSharding(mesh, PS())}

    def jit_with(batch_ns):
        return jax.jit(step,
                       in_shardings=(p_ns, o_ns, batch_ns),
                       out_shardings=(p_ns, o_ns, metric_ns),
                       donate_argnums=(0, 1))

    return step, jit_with, p_ns, o_ns, opt


def _bspec(mesh, batch: int):
    from repro.launch.mesh import dp_axes

    dp = dp_axes(mesh)
    return dp if batch > 1 and batch % S._axsize(mesh, dp) == 0 else None


def build_prefill_step(cfg, mesh, cell):
    """step(params, batch) -> (last_logits, cache)."""
    p_ns = ns(mesh, T.param_pspecs(cfg, mesh))
    cache_abs, cache_ps = S.cache_specs(cfg, cell, mesh)
    bspec = _bspec(mesh, cell.batch)
    act_ns = NamedSharding(mesh, PS(bspec, None, None))

    def step(params, batch):
        cache = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype),
                             cache_abs)
        hidden, _, cache = T.forward(cfg, params, batch, mode="prefill",
                                     cache=cache, act_sharding=act_ns)
        logits = T.logits_from_hidden(cfg, params, hidden[:, -1:, :])
        return logits, cache

    logits_ns = NamedSharding(mesh, PS(bspec, None, "model"))

    def jit_with(batch_ns):
        return jax.jit(step, in_shardings=(p_ns, batch_ns),
                       out_shardings=(logits_ns, ns(mesh, cache_ps)))

    return step, jit_with, p_ns


def build_serve_step(cfg, mesh, cell):
    """step(params, cache, batch) -> (next_token, cache).  One decode token
    against a KV/state cache of cell.seq."""
    from repro.launch.mesh import dp_axes

    p_ns = ns(mesh, T.param_pspecs(cfg, mesh))
    cache_abs, cache_ps = S.cache_specs(cfg, cell, mesh)
    c_ns = ns(mesh, cache_ps)
    bspec = _bspec(mesh, cell.batch)
    act_ns = NamedSharding(mesh, PS(bspec, None, None))

    def step(params, cache, batch):
        hidden, _, cache = T.forward(cfg, params, batch, mode="decode",
                                     cache=cache, act_sharding=act_ns)
        logits = T.logits_from_hidden(cfg, params, hidden)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache
    tok_ns = NamedSharding(mesh, PS(bspec, None))

    def jit_with(batch_ns):
        return jax.jit(step, in_shardings=(p_ns, c_ns, batch_ns),
                       out_shardings=(tok_ns, c_ns),
                       donate_argnums=(1,))

    return step, jit_with, p_ns, cache_abs, c_ns
