"""Paper Fig. 7 / Table 3: response time of iPHC-baseline vs TCD vs OTCD
(+ wave-mode OTCD, beyond paper) on selected valid queries."""

from __future__ import annotations

from repro.core import PHCIndex, iphc_query

from benchmarks.common import GRAPH_K, emit, engine, graph, pick_queries, \
    timeit


def run(per_graph: int = 4, span_uts: int = 70):
    rows = []
    qid = 0
    for name in ("collegemsg", "email", "mathoverflow"):
        g = graph(name)
        eng = engine(name)
        for q in pick_queries(name, per_graph, span_uts=span_uts):
            k = q["k"]
            qid += 1
            ts, te = q["ts"], q["te"]
            t_otcd = timeit(lambda: eng.query(k, ts, te), repeat=2)
            t_wave = timeit(
                lambda: eng.query(k, ts, te, mode="wave", wave=16), repeat=2)
            t_tcd = timeit(lambda: eng.query(k, ts, te, algorithm="tcd"))
            idx = PHCIndex(g, k, ts, te)
            t_iphc = timeit(lambda: iphc_query(g, idx, k, ts, te))
            res = eng.query(k, ts, te)
            iphc_res = iphc_query(g, idx, k, ts, te)
            assert set(c.tti for c in res.cores) == \
                set(c.tti for c in iphc_res.cores), (name, ts, te)
            rows.append({
                "id": qid, "graph": name, "k": k, "ts": ts, "te": te,
                "span_s": te - ts, "n_results": len(res),
                "t_otcd_s": t_otcd, "t_otcd_wave_s": t_wave,
                "t_tcd_s": t_tcd, "t_iphc_online_s": t_iphc,
                "t_phc_index_build_s": idx.build_time_s,
                "phc_index_bytes": idx.nbytes(),
                "speedup_otcd_vs_tcd": t_tcd / t_otcd,
                "speedup_otcd_vs_iphc": t_iphc / t_otcd,
                "cells_evaluated_otcd": res.stats.cells_evaluated,
                "cells_total": res.stats.cells_total,
            })
    emit("bench_queries", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
