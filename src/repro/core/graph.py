"""ArrayTEL: the TPU-native re-think of the paper's Temporal Edge List.

The paper's TEL is three dimensions of doubly-linked lists (timeline, source
list, destination list) supporting O(1) edge deletion on a CPU.  Pointers do
not exist on a TPU; the idiomatic equivalent is a structure-of-arrays with
boolean liveness masks:

  * edges are stored once, canonically sorted by ``(pair_id, t)`` so that the
    edge->pair segment reduction (distinct-neighbour degree semantics) sees
    *sorted* segment ids — which is what lets the Pallas kernel turn the
    reduction into a banded one-hot matmul on the MXU;
  * the "timeline" is the sorted unique-timestamp table plus per-edge
    timestamps; window truncation becomes a vectorized compare (or, in the
    time-sorted permutation kept for kernels, a contiguous rank range);
  * "deletion" is a mask update; the memory bound of the paper (space of the
    initial TEL only, no intermediates) is preserved: peeling state is one
    bool per vertex per in-flight query.

Host-side construction is numpy; ``device_tel()`` ships immutable arrays to
the accelerator once per graph.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import numpy as np


class DeviceTEL(NamedTuple):
    """Immutable device-resident temporal edge list (pytree of arrays).

    Shapes: E edges, P distinct vertex pairs ("links"), V vertices.
    Edges are sorted by (pair_id, t); pairs are sorted by (u, v) with u < v;
    half-pairs (2P incidences) are sorted by their vertex id.
    """

    src: np.ndarray        # [E] int32
    dst: np.ndarray        # [E] int32
    t: np.ndarray          # [E] int32 timestamps
    pair_id: np.ndarray    # [E] int32, sorted ascending
    pair_u: np.ndarray     # [P] int32 (u < v)
    pair_v: np.ndarray     # [P] int32
    hp_src: np.ndarray     # [2P] int32, sorted ascending (vertex of incidence)
    hp_pair: np.ndarray    # [2P] int32 (pair of incidence)
    time_perm: np.ndarray  # [E] int32: argsort(t) — timeline order for kernels

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_pairs(self) -> int:
        return int(self.pair_u.shape[0])


@dataclasses.dataclass(frozen=True)
class TemporalGraph:
    """Host-side temporal multigraph in canonical ArrayTEL layout."""

    src: np.ndarray          # [E] int32, canonical order (pair_id, t)
    dst: np.ndarray          # [E] int32
    t: np.ndarray            # [E] int32
    pair_id: np.ndarray      # [E] int32 ascending
    pair_u: np.ndarray       # [P] int32
    pair_v: np.ndarray       # [P] int32
    num_vertices: int
    unique_ts: np.ndarray    # sorted unique timestamps

    # ------------------------------------------------------------------ build
    @staticmethod
    def from_edges(u, v, t, num_vertices: Optional[int] = None) -> "TemporalGraph":
        """Build from parallel arrays of (u, v, t) temporal edges.

        Self loops are dropped (they never contribute to distinct-neighbour
        degree).  Endpoints are normalized to u < v for pair identity — the
        graph is undirected, matching the paper's data model.
        """
        u = np.asarray(u, dtype=np.int64)
        v = np.asarray(v, dtype=np.int64)
        t = np.asarray(t, dtype=np.int64)
        if not (u.shape == v.shape == t.shape):
            raise ValueError("u, v, t must have identical shapes")
        keep = u != v
        u, v, t = u[keep], v[keep], t[keep]
        lo = np.minimum(u, v)
        hi = np.maximum(u, v)
        if num_vertices is None:
            num_vertices = int(hi.max()) + 1 if hi.size else 0
        # factorize pairs: sort by (lo, hi, t) then run-length encode
        order = np.lexsort((t, hi, lo))
        lo, hi, t = lo[order], hi[order], t[order]
        if lo.size:
            new_pair = np.empty(lo.shape, dtype=bool)
            new_pair[0] = True
            new_pair[1:] = (lo[1:] != lo[:-1]) | (hi[1:] != hi[:-1])
            pair_id = np.cumsum(new_pair) - 1
            pair_u = lo[new_pair]
            pair_v = hi[new_pair]
        else:
            pair_id = np.zeros(0, dtype=np.int64)
            pair_u = np.zeros(0, dtype=np.int64)
            pair_v = np.zeros(0, dtype=np.int64)
        return TemporalGraph(
            src=lo.astype(np.int32),
            dst=hi.astype(np.int32),
            t=t.astype(np.int32),
            pair_id=pair_id.astype(np.int32),
            pair_u=pair_u.astype(np.int32),
            pair_v=pair_v.astype(np.int32),
            num_vertices=int(num_vertices),
            unique_ts=np.unique(t).astype(np.int32),
        )

    @staticmethod
    def from_edge_list(edges, num_vertices: Optional[int] = None) -> "TemporalGraph":
        """Build from an iterable of (u, v, t) triples."""
        arr = np.asarray(list(edges), dtype=np.int64)
        if arr.size == 0:
            arr = arr.reshape(0, 3)
        return TemporalGraph.from_edges(arr[:, 0], arr[:, 1], arr[:, 2], num_vertices)

    # --------------------------------------------------------------- dynamic
    def add_edges(self, u, v, t) -> "TemporalGraph":
        """Dynamic-graph extension (paper §6.1): amortized batch append.

        The paper appends one edge in O(1) by pointer surgery; the array
        equivalent is a batched rebuild of the (pair_id, t) ordering, O(E log E)
        amortized over the batch.  Timestamps may be arbitrary (late data is
        allowed — stricter than the paper, which assumes monotone arrival).
        """
        u = np.asarray(u, dtype=np.int32)
        v = np.asarray(v, dtype=np.int32)
        t = np.asarray(t, dtype=np.int32)
        if not (u.shape == v.shape == t.shape):
            raise ValueError("u, v, t must have identical shapes")
        if u.size == 0:
            return self
        u_all = np.concatenate([self.src, u])
        v_all = np.concatenate([self.dst, v])
        t_all = np.concatenate([self.t, t])
        n_vert = max(self.num_vertices, int(max(np.max(u), np.max(v))) + 1)
        return TemporalGraph.from_edges(u_all, v_all, t_all, n_vert)

    # ----------------------------------------------------------------- views
    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_pairs(self) -> int:
        return int(self.pair_u.shape[0])

    @property
    def span(self):
        if self.t.size == 0:
            return (0, 0)
        return (int(self.t.min()), int(self.t.max()))

    def window_counts(self, ts: int, te: int):
        """(#edges, #unique timestamps) inside [ts, te] — host-side metadata."""
        m = (self.t >= ts) & (self.t <= te)
        return int(m.sum()), int(np.unique(self.t[m]).size)

    def device_tel(self) -> DeviceTEL:
        """Ship to device.  Half-pair incidence is derived here (sorted by
        vertex) so the degree reduction also sees sorted segment ids."""
        import jax.numpy as jnp

        p = self.num_pairs
        hp_src = np.concatenate([self.pair_u, self.pair_v])
        hp_pair = np.concatenate(
            [np.arange(p, dtype=np.int32), np.arange(p, dtype=np.int32)]
        )
        order = np.argsort(hp_src, kind="stable")
        time_perm = np.argsort(self.t, kind="stable").astype(np.int32)
        return DeviceTEL(
            src=jnp.asarray(self.src),
            dst=jnp.asarray(self.dst),
            t=jnp.asarray(self.t),
            pair_id=jnp.asarray(self.pair_id),
            pair_u=jnp.asarray(self.pair_u),
            pair_v=jnp.asarray(self.pair_v),
            hp_src=jnp.asarray(hp_src[order].astype(np.int32)),
            hp_pair=jnp.asarray(hp_pair[order].astype(np.int32)),
            time_perm=jnp.asarray(time_perm),
        )

    def memory_bytes(self) -> int:
        """ArrayTEL footprint (paper Table 5 analogue)."""
        per_edge = 4 * 4 + 4  # src,dst,t,pair_id + time_perm
        per_pair = 4 * 2 + 4 * 2 * 2  # pair_u/v + half pairs (src,pair)x2
        return self.num_edges * per_edge + self.num_pairs * per_pair
