"""Substrate tests: optimizers, compression, checkpointing, fault-tolerant
trainer (failure injection -> restart-exact resume), straggler watchdog,
elastic resharding, data-pipeline determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMData
from repro.optim import (Adafactor, AdamW, compressed_psum_exact,
                         dequantize_int8, quantize_int8)


# --------------------------------------------------------------- optimizers
@pytest.mark.parametrize("opt", [AdamW(lr=0.1), Adafactor(lr=0.5)])
def test_optimizer_descends_quadratic(opt):
    params = {"w": jnp.asarray([3.0, -2.0, 5.0]),
              "m": jnp.ones((4, 6)) * 2.0}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["m"] ** 2)

    l0 = loss(params)
    for _ in range(60):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(loss(params)) < 0.05 * float(l0)


def test_opt_state_pspecs_match_structure():
    from jax.sharding import PartitionSpec as PS

    pspecs = {"w": PS("data", "model"), "b": PS(None)}
    adam = AdamW()
    st = adam.state_pspecs(pspecs)
    assert st["m"]["w"] == PS("data", "model")
    fac = Adafactor()
    st2 = fac.state_pspecs(pspecs)
    assert st2["f"]["w"]["vr"] == PS("data")
    assert st2["f"]["w"]["vc"] == PS("model")


# -------------------------------------------------------------- compression
def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3, (256, 64)), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x)).max()
    assert err <= float(s) * 0.5 + 1e-6


def test_compressed_psum_with_error_feedback():
    """On a 1-device axis the compressed psum must equal the input up to
    quantization error, and error feedback must carry the residual."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS

    mesh = jax.make_mesh((1,), ("d",))
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (64,)),
                    jnp.float32)
    err = jnp.zeros_like(x)
    fn = shard_map(lambda a, e: compressed_psum_exact(a, "d", e),
                   mesh=mesh, in_specs=(PS(), PS()),
                   out_specs=(PS(), PS()), check_rep=False)
    out, new_err = fn(x, err)
    np.testing.assert_allclose(np.asarray(out + new_err), np.asarray(x),
                               rtol=1e-6, atol=1e-6)
    # accumulated mean over steps is unbiased thanks to error feedback
    total = jnp.zeros_like(x)
    e = jnp.zeros_like(x)
    for _ in range(50):
        o, e = fn(x, e)
        total = total + o
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(x),
                               rtol=0.02, atol=0.02)


# ------------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip_and_prune(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray(7, jnp.int32)}}
    for step in (1, 2, 3):
        mgr.save(step, tree)
    assert mgr.steps() == [2, 3]  # pruned to keep=2
    out = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.int32


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones((4,))}
    path = mgr.save(1, tree)
    target = os.path.join(path, "w.npy")
    with open(target, "r+b") as f:
        f.seek(-1, 2)
        f.write(b"\x42")
    with pytest.raises(IOError):
        mgr.restore(tree, verify=True)


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    tree = {"w": jnp.ones((128, 128))}
    mgr.save(5, tree)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_tmp_dir_is_not_a_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "step_000009.tmp"))
    assert mgr.latest_step() is None  # crash-atomic: tmp dirs invisible


# ------------------------------------------------------------ data pipeline
def test_data_restart_exact():
    a = SyntheticLMData(vocab=100, batch=4, seq=8, seed=3)
    b = SyntheticLMData(vocab=100, batch=4, seq=8, seed=3)
    for step in (0, 7, 123):
        x, y = a.batch_at(step), b.batch_at(step)
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    assert not np.array_equal(a.batch_at(1)["tokens"],
                              a.batch_at(2)["tokens"])


def test_data_host_sharding():
    full = SyntheticLMData(vocab=50, batch=8, seq=4, seed=1)
    h0 = SyntheticLMData(vocab=50, batch=8, seq=4, seed=1, host_id=0,
                         n_hosts=2)
    assert h0.batch_at(0)["tokens"].shape[0] == 4
    assert full.batch_at(0)["tokens"].shape[0] == 8


# ------------------------------------------------- fault-tolerant training
def _tiny_trainer(tmp_path, injector=None, steps=8):
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.runtime import Trainer, TrainerConfig

    cfg = get_smoke_config("qwen2-7b").scaled(n_layers=2)
    mesh = make_host_mesh()
    data = SyntheticLMData(vocab=cfg.vocab, batch=4, seq=16, seed=0)
    tcfg = TrainerConfig(steps=steps, ckpt_every=3,
                         ckpt_dir=str(tmp_path), lr=1e-3)
    return Trainer(cfg, mesh, data, tcfg, injector=injector)


def test_trainer_runs_and_checkpoints(tmp_path):
    tr = _tiny_trainer(tmp_path)
    out = tr.run()
    assert out["steps_run"] == 8
    assert np.isfinite(out["final_loss"])
    assert tr.ckpt.latest_step() == 8


def test_trainer_survives_injected_failure(tmp_path):
    from repro.runtime import FaultInjector

    tr = _tiny_trainer(tmp_path, FaultInjector(fail_at={5: "node loss"}))
    out = tr.run()
    assert out["restarts"] == 1
    # restart-exact: steps 3..4 replayed after restoring the step-3 ckpt
    steps_seen = [m["step"] for m in tr.metrics]
    assert steps_seen.count(4) == 2 and steps_seen[-1] == 7


def test_trainer_restart_budget(tmp_path):
    from repro.runtime import FaultInjector, InjectedFault

    inj = FaultInjector(fail_at={2: "a"})
    inj._fired = set()  # re-fire forever

    class Always(FaultInjector):
        def check(self, step):
            if step == 2:
                raise InjectedFault("flaky node")

    tr = _tiny_trainer(tmp_path, Always(), steps=4)
    with pytest.raises(RuntimeError, match="restart budget"):
        tr.run()


def test_straggler_watchdog(tmp_path):
    from repro.runtime import FaultInjector

    tr = _tiny_trainer(tmp_path, FaultInjector(delay_at={6: 1.5}))
    tr.run()
    assert tr.straggler_flags >= 1


def test_elastic_restore_across_meshes(tmp_path):
    """Save under one mesh, restore under another (reshard-on-restore)."""
    from jax.sharding import NamedSharding, PartitionSpec as PS

    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"w": jax.device_put(
        jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        NamedSharding(mesh1, PS("data", "model")))}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    mesh2 = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh2, PS("data", None))}
    out = mgr.restore(tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert out["w"].sharding == sh["w"]
