"""TCQ serving launcher: the paper's system answering batched time-range
k-core queries, optionally on a distributed (shard_map) engine.

    PYTHONPATH=src python -m repro.launch.serve --vertices 2000 \
        --edges 30000 --requests 16 [--distributed] [--combine rs_ag]
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=2_000)
    ap.add_argument("--edges", type=int, default=30_000)
    ap.add_argument("--span", type=int, default=16_384)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--wave", type=int, default=8)
    ap.add_argument("--distributed", action="store_true",
                    help="shard_map engine on the local host mesh")
    ap.add_argument("--combine", default="rs_ag",
                    choices=["psum", "rs_ag"])
    args = ap.parse_args()

    from repro.core import TCQEngine
    from repro.data import TCQRequestStream
    from repro.graphs import powerlaw_temporal

    g = powerlaw_temporal(args.vertices, args.edges, args.span, seed=3)
    lo, hi = g.span
    reqs = list(TCQRequestStream(lo, hi, k=args.k,
                                 span=max(64, args.span // 20),
                                 seed=0).requests(args.requests))

    if args.distributed:
        import jax

        from repro.core.distributed import DistributedTCQ
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh()
        eng = DistributedTCQ(g, mesh, combine=args.combine)
        t0 = time.perf_counter()
        alive, tlo, thi, ne, iters = eng.query_wave(
            [r["ts"] for r in reqs], [r["te"] for r in reqs], args.k)
        dt = time.perf_counter() - t0
        for i, r in enumerate(reqs):
            print(f"req#{r['id']:03d} window=[{r['ts']},{r['te']}] -> "
                  f"top-core TTI=[{int(tlo[i])},{int(thi[i])}] "
                  f"|E|={int(ne[i])}")
        print(f"[serve] distributed wave of {len(reqs)} on mesh "
              f"{dict(zip(mesh.axis_names, mesh.devices.shape))}: "
              f"{dt:.3f}s ({int(iters)} peel iterations)")
        return

    eng = TCQEngine(g)
    lat = []
    for r in reqs:
        t0 = time.perf_counter()
        res = eng.query(r["k"], r["ts"], r["te"], mode="wave",
                        wave=args.wave)
        lat.append(time.perf_counter() - t0)
        print(f"req#{r['id']:03d} window=[{r['ts']},{r['te']}] -> "
              f"{len(res)} distinct cores")
    print(f"[serve] {len(reqs)} requests, mean {np.mean(lat)*1e3:.1f} ms, "
          f"p95 {np.quantile(lat, 0.95)*1e3:.1f} ms")


if __name__ == "__main__":
    main()
