"""Paper Fig. 13: distribution of all distinct cores by TTI span (full-graph
scan), plus the Table 6 style burst listing (largest short-span cores)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, engine, graph


def run(name: str = "email", k: int = 4):
    """Paper's 'full graph scan' (their Youtube/10-core run took 55 min on
    real hardware); CPU-scaled to the email graph's middle half-span."""
    g = graph(name)
    eng = engine(name)
    lo, hi = g.span
    lo, hi = lo + (hi - lo) // 4, hi - (hi - lo) // 4
    res = eng.query(k, lo, hi, mode="wave", wave=32)
    spans = np.array([c.span for c in res.cores])
    hist, edges = np.histogram(spans, bins=10)
    bursts = sorted(res.cores, key=lambda c: (-c.n_vertices, c.span))[:5]
    rows = [{
        "graph": name, "k": k, "n_cores": len(res),
        "wall_s": res.stats.wall_time_s,
        "span_hist_counts": hist.tolist(),
        "span_hist_edges": edges.tolist(),
        "largest_short_cores": [
            {"tti": c.tti, "V": c.n_vertices, "E": c.n_edges}
            for c in bursts],
    }]
    emit("bench_distribution", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
