"""TTI core-cache correctness gates.

The cache (``repro.core.corecache``) leans on Property 2 — TTI equality
is subgraph identity for a fixed (k, h, snapshot) — plus the dominance
rule (a cell ``(ts, te) -> (lo, hi)`` resolves any queried window
``(a, b)`` with ``ts <= a <= lo`` and ``hi <= b <= te``).  Everything a
stale or over-eager cache could corrupt is fuzzed here:

1. **cached == recomputed** — overlapping/repeated windows through a
   cached engine match a cache-less engine bit-for-bit, and repeats
   actually hit (the cache is alive, not just harmless);
2. **ingest invalidation == cold rebuild** — after appends that land
   inside cached windows, warm results equal a from-scratch engine on
   the new snapshot (incremental invalidation is exact);
3. **oracle cross-check** — cached TTIs/cores agree with
   ``brute_force_query`` on small graphs;
4. **eviction under pressure** — a byte/cell-starved cache evicts but
   never serves a wrong (or phantom) core;
5. **snapshot round-trip** — ``save_snapshot``/``load_snapshot``
   restores a warm cache (restored repeats hit without peeling), and
   restoring with ``cache=False`` cleanly drops it.

``REPRO_CACHE_GATE=1`` widens the fuzz seeds (CI's ``cache_gate`` job
runs ``-m cache_gate``); the same tests run on the narrow seed set in
plain tier-1.
"""

import io
import os

import numpy as np
import pytest

from repro.core import (CoreCache, TCQEngine, TCQService, TemporalGraph,
                        brute_force_query)

CACHE_GATE = os.environ.get("REPRO_CACHE_GATE") == "1"
SEEDS = list(range(8)) if CACHE_GATE else list(range(3))


def random_graph(seed, n_v=20, n_e=140, max_t=16):
    rng = np.random.default_rng(seed)
    return TemporalGraph.from_edges(rng.integers(0, n_v, n_e),
                                    rng.integers(0, n_v, n_e),
                                    rng.integers(1, max_t + 1, n_e), n_v)


def random_windows(rng, uts, n):
    """Overlapping windows with deliberate repeats and sub-windows."""
    lo, hi = int(uts[0]), int(uts[-1])
    wins = []
    while len(wins) < n:
        a, b = sorted(rng.integers(lo, hi + 1, size=2).tolist())
        wins.append((int(a), int(b)))
        if len(wins) < n and rng.random() < 0.4:
            wins.append((int(a), int(b)))          # exact repeat
        if len(wins) < n and b - a > 2 and rng.random() < 0.4:
            m = int(rng.integers(a, b))            # sub-window (dominance)
            wins.append((int(m), int(b)))
    return wins[:n]


def assert_same(got, want, ctx=""):
    assert got.by_tti().keys() == want.by_tti().keys(), ctx
    for key, cw in want.by_tti().items():
        cg = got.by_tti()[key]
        assert np.array_equal(cg.vertices, cw.vertices), (ctx, key)
        assert cg.n_edges == cw.n_edges, (ctx, key)


# ------------------------------------------------ cached == recomputed fuzz
@pytest.mark.cache_gate
@pytest.mark.parametrize("seed", SEEDS)
def test_cached_matches_recomputed(seed):
    g = random_graph(seed)
    rng = np.random.default_rng(100 + seed)
    cached = TCQEngine(g, use_kernel=False, cache=True)
    plain = TCQEngine(g, use_kernel=False)
    k = int(rng.integers(2, 4))
    for a, b in random_windows(rng, g.unique_ts, 14):
        got = cached.query(k, a, b, mode="wave")
        want = plain.query(k, a, b, mode="wave")
        assert_same(got, want, f"seed={seed} k={k} [{a},{b}]")
    st = cached.core_cache.stats()
    assert st["hits"] + st["dominance_hits"] > 0   # repeats really hit
    assert plain.core_cache is None                # bare default stays off


# ------------------------------------- ingest invalidation == cold rebuild
@pytest.mark.cache_gate
@pytest.mark.parametrize("seed", SEEDS)
def test_ingest_invalidation_matches_cold_rebuild(seed):
    g = random_graph(seed, n_e=120)
    rng = np.random.default_rng(200 + seed)
    svc = TCQService(g, use_kernel=False, cache=True)
    uts = g.unique_ts
    wins = random_windows(rng, uts, 6)
    k = int(rng.integers(2, 4))
    for epoch in range(3):
        tks = [svc.submit({"k": k, "ts": a, "te": b}) for a, b in wins]
        svc.run_until_idle()
        cold = TCQEngine(svc.graph, use_kernel=False)
        for tk, (a, b) in zip(tks, wins):
            assert_same(tk.result, cold.query(k, a, b, mode="wave"),
                        f"seed={seed} epoch={epoch} [{a},{b}]")
        # append *inside* the live span so cached windows must invalidate
        n = 18
        svc.push_edges(rng.integers(0, g.num_vertices, n),
                       rng.integers(0, g.num_vertices, n),
                       rng.integers(int(uts[0]), int(uts[-1]) + 1, n))
    cc = svc.stats["core_cache"]
    assert cc["invalidated"] > 0                   # invalidation fired
    assert svc.epoch == 3


# ------------------------------------------------------- oracle cross-check
@pytest.mark.cache_gate
@pytest.mark.parametrize("seed", SEEDS[:4])
def test_cached_ttis_match_oracle(seed):
    g = random_graph(seed, n_v=12, n_e=60, max_t=8)
    eng = TCQEngine(g, use_kernel=False, cache=True)
    uts = g.unique_ts
    a, b = int(uts[0]), int(uts[-1])
    for _ in range(2):                             # second pass: cache-served
        got = eng.query(2, a, b, mode="wave")
        want = brute_force_query(g, 2, a, b)
        assert got.by_tti().keys() == want.keys()
        for key, core in got.by_tti().items():
            assert frozenset(core.vertices.tolist()) == \
                want[key]["vertices"], key
            assert core.n_edges == want[key]["n_edges"], key
    st = eng.core_cache.stats()
    assert st["hits"] + st["dominance_hits"] > 0


# ------------------------------------------------- eviction under pressure
@pytest.mark.cache_gate
@pytest.mark.parametrize("seed", SEEDS[:4])
def test_eviction_under_pressure_stays_correct(seed):
    g = random_graph(seed)
    rng = np.random.default_rng(300 + seed)
    tiny = CoreCache(max_bytes=256, max_cells=6)
    cached = TCQEngine(g, use_kernel=False, cache=tiny)
    plain = TCQEngine(g, use_kernel=False)
    for a, b in random_windows(rng, g.unique_ts, 16):
        assert_same(cached.query(2, a, b, mode="wave"),
                    plain.query(2, a, b, mode="wave"),
                    f"seed={seed} [{a},{b}]")
    st = tiny.stats()
    assert st["evicted_cores"] + st["evicted_cells"] > 0
    assert st["bytes"] <= 256 and st["n_cells"] <= 6


# ------------------------------------------------ snapshot/restore round-trip
def test_snapshot_restores_warm_cache():
    g = random_graph(7)
    rng = np.random.default_rng(7)
    svc = TCQService(g, use_kernel=False, cache=True)
    wins = random_windows(rng, g.unique_ts, 6)
    tks = [svc.submit({"k": 2, "ts": a, "te": b}) for a, b in wins]
    svc.run_until_idle()
    n_cores = svc.stats["core_cache"]["n_cores"]
    assert n_cores > 0

    buf = io.BytesIO()
    svc.save_snapshot(buf)
    buf.seek(0)
    svc2 = TCQService.load_snapshot(buf, use_kernel=False, cache=True)
    cc2 = svc2.engine.core_cache
    assert cc2.stats()["n_cores"] == n_cores
    assert cc2.stats()["n_cells"] == svc.stats["core_cache"]["n_cells"]

    # restored repeats are cache-served (no peeling) and bit-identical
    tks2 = [svc2.submit({"k": 2, "ts": a, "te": b}) for a, b in wins]
    svc2.run_until_idle()
    for tk, tk2 in zip(tks, tks2):
        assert_same(tk2.result, tk.result, f"[{tk.ts},{tk.te}]")
        assert tk2.result.stats.cells_cached > 0
        assert tk2.result.stats.cells_evaluated == 0


def test_snapshot_restore_without_cache_drops_cleanly():
    g = random_graph(9)
    svc = TCQService(g, use_kernel=False, cache=True)
    svc.submit({"k": 2, "ts": int(g.unique_ts[0]),
                "te": int(g.unique_ts[-1])})
    svc.run_until_idle()
    buf = io.BytesIO()
    svc.save_snapshot(buf)
    buf.seek(0)
    svc2 = TCQService.load_snapshot(buf, use_kernel=False, cache=False)
    assert svc2.engine.core_cache is None          # state dropped, no error
    tk = svc2.submit({"k": 2, "ts": int(g.unique_ts[0]),
                      "te": int(g.unique_ts[-1])})
    svc2.run_until_idle()
    want = TCQEngine(g, use_kernel=False).query(
        2, int(g.unique_ts[0]), int(g.unique_ts[-1]), mode="wave")
    assert_same(tk.result, want)


# ----------------------------------------------------- CoreCache unit seams
def test_dominance_and_empty_cells():
    cc = CoreCache()
    row = np.asarray([0b101], dtype=np.uint32)
    cc.insert(0, 2, 1, ts=2, te=12, lo=5, hi=9, n_edges=4, packed=row)
    # ts <= a <= lo and hi <= b <= te -> dominated, same TTI/core
    hit = cc.lookup(0, 2, 1, 4, 10)
    assert hit is not None and (hit.tti_lo, hit.tti_hi) == (5, 9)
    assert np.array_equal(hit.packed, row)
    assert cc.lookup(0, 2, 1, 6, 10) is None       # a > lo: not dominated
    cc.insert_empty(0, 2, 1, 20, 30)
    empty = cc.lookup(0, 2, 1, 22, 28)             # sub-window of empty
    assert empty is not None and empty.n_edges == 0 and empty.packed is None
    assert cc.lookup(0, 3, 1, 4, 10) is None       # other k: separate group


def test_advance_epoch_window_vs_tti_invalidation():
    cc = CoreCache()
    row = np.asarray([0b11], dtype=np.uint32)
    cc.insert(0, 2, 1, ts=0, te=10, lo=2, hi=8, n_edges=3, packed=row)
    cc.insert(0, 2, 1, ts=40, te=50, lo=42, hi=48, n_edges=3, packed=row)
    inv, rek = cc.advance_epoch(0, 1, batch_lo=5, batch_hi=6)
    assert inv > 0 and rek > 0
    assert cc.lookup(1, 2, 1, 0, 10) is None       # window hit batch: gone
    hit = cc.lookup(1, 2, 1, 40, 50)               # disjoint: re-keyed
    assert hit is not None and (hit.tti_lo, hit.tti_hi) == (42, 48)
    # survivors are *moved*, not copied: old-epoch probes now miss
    # (a safe miss — pinned queries recompute; never a stale serve)
    assert cc.lookup(0, 2, 1, 40, 50) is None
