"""Fused Pallas peel-to-fixpoint wave step (kernel + dispatch + cost
model).  See kernel.py for the design; ``core.wave.make_wave_step_fn``
is the routing entry point used by the engines."""

from repro.kernels.wave_peel.kernel import (segment_bounds,  # noqa: F401
                                            wave_peel_pallas)
from repro.kernels.wave_peel.ops import (fused_step_cost,  # noqa: F401
                                         fused_step_vmem_bytes,
                                         make_fused_wave_step)
