"""Sharded checkpointing: atomic manifests, async save, restore-with-reshard.

Layout per step:
    <dir>/step_000123/
        manifest.json   (tree structure, shapes, dtypes, sha256 per leaf —
                         written LAST; a directory without a manifest is
                         garbage by definition => crash-atomic)
        <leafkey>.npy   one file per pytree leaf

Restore takes target shardings (NamedShardings for a possibly DIFFERENT
mesh) and device_puts each leaf — this is the elastic-rescale path: save on
16x16, restore on 8x16 or 2x16x16 without any conversion step.  At true
multi-host scale each host would write only its addressable shards; the
manifest format already carries per-leaf shape/dtype so that extension is
additive (documented in DESIGN.md).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any) -> str:
        self.wait()
        # materialize on host BEFORE going async (snapshot semantics)
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        host = [(_leaf_key(p), np.asarray(l)) for p, l in leaves]
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)
        return self._step_dir(step)

    def _write(self, step: int, host_leaves) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest: Dict[str, Any] = {"step": step, "time": time.time(),
                                    "leaves": {}}
        for key, arr in host_leaves:
            fp = os.path.join(tmp, key + ".npy")
            np.save(fp, arr)
            manifest["leaves"][key] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "sha256": _sha256(fp),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._prune()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None, verify: bool = True) -> Any:
        """Restore into the structure of ``like`` (pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: matching pytree of (Named)
        Shardings for the TARGET mesh — the reshard happens here."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoints in " + self.dir)
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
            if shardings is not None else [None] * len(paths))
        out = []
        for (path, leaf), sh in zip(paths, shard_leaves):
            key = _leaf_key(path)
            fp = os.path.join(d, key + ".npy")
            meta = manifest["leaves"][key]
            if verify and _sha256(fp) != meta["sha256"]:
                raise IOError(f"checkpoint corruption in {fp}")
            arr = np.load(fp)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ----------------------------------------------------------------- misc
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:06d}")

    def _prune(self) -> None:
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)


def reshard(tree: Any, shardings: Any) -> Any:
    """Elastic re-mesh: move a live pytree onto new shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), tree, shardings,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))
