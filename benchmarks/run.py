"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and writes
full JSON records under benchmarks/results/.  The wave-engine rows
(bench_wave + its fused-kernel gate run_kernel + bench_pipeline +
bench_service + bench_streaming + bench_cache + bench_chaos incl. its
kill-anywhere durability drill + bench_distributed) are
additionally folded into the
repo-root ``BENCH_wave.json`` so the wave-mode perf trajectory is
tracked across PRs; bench_wave.run_kernel raises on fused-vs-composite
bit divergence or a fused-cost regression, and bench_pipeline,
bench_service and bench_streaming verify cross-engine result
equivalence (including the streaming snapshot-consistency gate) and
raise (non-zero exit) on divergence, so the harness doubles as a
regression gate.  With ``REPRO_BENCH_SMOKE=1`` only the gate benches run,
on shrunken graphs, and the trajectory file is left untouched — that is
the per-PR CI mode.  The dry-run / roofline tables are produced by
``python -m repro.launch.dryrun`` and ``python -m benchmarks.roofline``
(they need the 512-device env and are kept out of this CPU-timing
harness).
"""

from __future__ import annotations

import json
import os
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_cache, bench_chaos, bench_distributed,
                            bench_distribution, bench_k, bench_memory,
                            bench_pipeline, bench_pruning, bench_queries,
                            bench_service, bench_span, bench_streaming,
                            bench_wave)
    from benchmarks.common import SMOKE

    print("name,us_per_call,derived")
    failures = 0
    trajectory = {}

    def row(name, seconds, derived=""):
        print(f"{name},{seconds * 1e6:.1f},{derived}")

    try:
        for r in ([] if SMOKE else bench_queries.run()):
            tag = f"queries/{r['graph']}/q{r['id']}"
            row(tag + "/otcd", r["t_otcd_s"],
                f"results={r['n_results']}")
            row(tag + "/otcd_wave", r["t_otcd_wave_s"],
                f"steps<=cells={r['cells_evaluated_otcd']}")
            row(tag + "/tcd", r["t_tcd_s"],
                f"speedup_otcd={r['speedup_otcd_vs_tcd']:.1f}x")
            row(tag + "/iphc_online", r["t_iphc_online_s"],
                f"speedup_otcd={r['speedup_otcd_vs_iphc']:.1f}x")
    except Exception:
        failures += 1
        traceback.print_exc()

    try:
        for r in ([] if SMOKE else bench_pruning.run()):
            row(f"pruning/{r['graph']}", 0.0,
                f"pruned%={r['pct_total_pruned']:.1f} "
                f"(por={r['pct_por']:.1f} pou={r['pct_pou']:.1f} "
                f"pol={r['pct_pol']:.1f} empty={r['pct_empty']:.1f})")
    except Exception:
        failures += 1
        traceback.print_exc()

    try:
        for r in ([] if SMOKE else bench_k.run()):
            row(f"impact_k/{r['graph']}/k{r['k']}", r["t_otcd_s"],
                f"cores={r['n_cores']} cc={r['n_components']} "
                f"tcd_s={r['t_tcd_s']:.3f}")
    except Exception:
        failures += 1
        traceback.print_exc()

    try:
        for r in ([] if SMOKE else bench_span.run()):
            row(f"impact_span/{r['graph']}/x{r['span_uts']}",
                r["t_otcd_s"],
                f"cells={r['cells_total']} cores={r['n_cores']} "
                f"tcd_s={r['t_tcd_s']:.3f}")
    except Exception:
        failures += 1
        traceback.print_exc()

    try:
        for r in ([] if SMOKE else bench_memory.run()):
            row(f"memory/{r['graph']}", 0.0,
                f"tel_bytes={r['tel_bytes']} "
                f"bytes_per_edge={r['tel_bytes_per_edge']:.1f}")
    except Exception:
        failures += 1
        traceback.print_exc()

    try:
        for r in ([] if SMOKE else bench_distribution.run()):
            row(f"distribution/{r['graph']}", r["wall_s"],
                f"cores={r['n_cores']}")
    except Exception:
        failures += 1
        traceback.print_exc()

    try:
        wrows = bench_wave.run()
        trajectory["wave"] = wrows
        for r in wrows:
            if r["bench"] == "wave_width":
                row(f"wave/width{r['wave']}", r["t_s"],
                    f"device_steps={r['device_steps']}")
            else:
                row(f"wave/degree_{r['path']}", r["t_s"],
                    f"iters={r['iters']}")
    except Exception:
        failures += 1
        traceback.print_exc()

    try:
        # the fused wave-peel kernel gate: run_kernel() raises on any
        # fused-vs-composite bit divergence and on a cost-model
        # regression (fused bytes/step must stay strictly below the
        # unfused chain), so a broken kernel fails the harness like a
        # cross-engine result divergence would
        krows = bench_wave.run_kernel()
        trajectory["kernel"] = krows
        for r in krows:
            if r["bench"] == "fused_step":
                row(f"kernel/{r['path']}", r["t_s"],
                    f"iters={r['iters']} wave={r['wave']}")
            else:
                row("kernel/cost", 0.0,
                    f"bytes_ratio={r['bytes_ratio']:.2e} "
                    f"fused_B/step={r['fused_bytes_step']:.3e} "
                    f"unfused_B/step={r['unfused_bytes_step']:.3e} "
                    f"gate_ok={r['gate_ok']}")
    except Exception:
        failures += 1
        traceback.print_exc()

    try:
        prows = bench_pipeline.run()
        trajectory["pipeline"] = prows
        for r in prows:
            if r["bench"] == "pipeline":
                row(f"pipeline/{r['mode']}", r["t_s"],
                    f"steps={r['device_steps']} syncs={r['host_syncs']} "
                    f"bytes/step={r['bytes_per_step']:.0f}")
            else:
                row("pipeline/speedup", 0.0,
                    f"wave_vs_serial="
                    f"{r['speedup_wave_vs_serial']:.2f}x")
    except Exception:
        failures += 1
        traceback.print_exc()

    try:
        srows = bench_service.run()
        trajectory["service"] = srows
        for r in srows:
            if r["bench"] == "service":
                extra = (f" occ={r['occupancy']:.2f}"
                         if "occupancy" in r else "")
                row(f"service/{r['mode']}", r["t_s"],
                    f"qps={r['qps']:.2f}{extra}")
            else:
                row("service/speedup", 0.0,
                    f"batch_vs_serial_loop="
                    f"{r['speedup_batch_vs_serial_loop']:.2f}x "
                    f"batch_vs_wave_loop="
                    f"{r['speedup_batch_vs_wave_loop']:.2f}x")
    except Exception:
        failures += 1
        traceback.print_exc()

    try:
        strows = bench_streaming.run()
        trajectory["streaming"] = strows
        for r in strows:
            if r["bench"] == "streaming":
                row(f"streaming/{r['mode']}", r["t_s"],
                    f"qps={r['qps']:.2f} occ={r['occupancy']:.2f}")
            elif r["bench"] == "streaming_ingest":
                row("streaming/ingest", r["t_s"],
                    f"qps={r['qps']:.2f} epochs={r['epochs_ingested']} "
                    f"p95={r['p95_ms']:.0f}ms "
                    f"midflight={r['admitted_midflight']}")
            else:
                row("streaming/speedup", 0.0,
                    f"clustered_vs_union="
                    f"{r['speedup_clustered_vs_union']:.2f}x "
                    f"(union_E={r['union_window_edges']} "
                    f"cluster_E<={r['max_cluster_window_edges']})")
    except Exception:
        failures += 1
        traceback.print_exc()

    try:
        # cache gate: warm-vs-cold equivalence (bit-identity, including
        # across interleaved ingest epochs), a warm-speedup floor and a
        # hit-rate floor — the module raises on any violation, so a
        # stale or dead cache fails the harness like a wrong core would
        carows = bench_cache.run()
        trajectory["cache"] = carows
        for r in carows:
            if r["bench"] == "cache":
                extra = (f" hit_rate={r['hit_rate']:.2f}"
                         if "hit_rate" in r else "")
                row(f"cache/{r['mode']}", r["t_s"],
                    f"qps={r['qps']:.2f}{extra}")
            elif r["bench"] == "cache_ingest":
                row("cache/ingest", r["t_s"],
                    f"epochs={r['epochs']} verified={r['verified']} "
                    f"invalidated={r['invalidated']} "
                    f"rekeyed={r['rekeyed']} "
                    f"equivalent={r['equivalent']}")
            else:
                row("cache/speedup", 0.0,
                    f"warm_vs_cold={r['speedup_warm_vs_cold']:.2f}x "
                    f"hit_rate={r['hit_rate']:.2%} "
                    f"gate_ok={r['gate_ok']}")
    except Exception:
        failures += 1
        traceback.print_exc()

    try:
        # chaos gate: every fault scenario must stay bit-identical to
        # the fault-free run (the module raises otherwise), so injected
        # kernel failures / corruption / crashes fail the harness just
        # like a wrong core would
        crows = bench_chaos.run()
        trajectory["chaos"] = crows
        for r in crows:
            if r["bench"] == "chaos":
                row(f"chaos/{r['scenario']}/s{r['seed']}",
                    r.get("wall_s", 0.0),
                    f"equivalent={r['equivalent']} "
                    f"demotions={r.get('demotions', 0)}")
            else:
                row("chaos/overload", r["wall_s"],
                    f"shed_rate={r['shed_rate']:.2f} "
                    f"p99={r['p99_ms']:.0f}ms "
                    f"timeouts={r['timeouts']}")
    except Exception:
        failures += 1
        traceback.print_exc()

    try:
        # durability gate: the kill-anywhere drill (crash after every
        # journal record + torn/corrupt post-mortems) must recover to a
        # bit-identical drain over each surviving prefix — the module
        # raises on any divergence, lost admission, or lineage mismatch
        wrows = bench_chaos.run_durability()
        trajectory["durability"] = wrows
        for r in wrows:
            if r["scenario"] == "kill":
                row(f"durability/kill@{r['crash_after_record']}",
                    r["recover_s"],
                    f"tail={r['tail_records']} "
                    f"requeued={r['requeued']} "
                    f"equivalent={r['equivalent']}")
            elif r["scenario"] == "summary":
                row("durability/summary", r["max_recover_s"],
                    f"records={r['journal_records']} "
                    f"kill_points={r['kill_points']}")
            else:
                row(f"durability/{r['scenario']}", r["recover_s"],
                    f"tail={r['tail_records']} "
                    f"skipped_snaps={r.get('snapshots_skipped', 0)} "
                    f"equivalent={r['equivalent']}")
    except Exception:
        failures += 1
        traceback.print_exc()

    try:
        # distributed gate: every mesh shape must stay bit-identical to
        # the single-device drain, and the best shape must clear the
        # aggregate-qps floor (the module raises on either violation)
        drows = bench_distributed.run()
        trajectory["distributed"] = drows
        for r in drows:
            if r["bench"] == "distributed":
                row(f"distributed/{r['mesh']}", r["t_s"],
                    f"qps={r['qps']:.2f} speedup={r['speedup']:.2f}x "
                    f"eff={r['efficiency']:.2f} "
                    f"combine={r['combine']} "
                    f"equivalent={r['equivalent']}")
            else:
                row("distributed/speedup", 0.0,
                    f"best={r['best_mesh']} "
                    f"{r['speedup']:.2f}x floor={r['floor']}x "
                    f"gate_ok={r['gate_ok']}")
    except Exception:
        failures += 1
        traceback.print_exc()

    # only a complete trajectory may replace the tracked file — a partial
    # write would clobber the last good cross-PR history (and smoke-sized
    # runs never overwrite the measured numbers)
    if not SMOKE and \
            {"wave", "kernel", "pipeline", "service", "streaming",
             "cache", "chaos", "durability",
             "distributed"} <= trajectory.keys():
        out = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_wave.json")
        with open(out, "w") as f:
            json.dump(trajectory, f, indent=1, default=str)

    if failures:
        print(f"# {failures} bench module(s) failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
