"""GQA attention: train/prefill (chunked online-softmax), decode (cached KV).

Covers every attention variant in the zoo: grouped KV (any ratio), sliding
window (gemma2 local layers), attention-logit softcap (gemma2), QKV bias
(qwen2), M-RoPE (qwen2-vl), MQA (granite-34b, kv=1), cross-attention
(whisper decoder), bidirectional encoders.

Masks are built from sequence RANKS (iota), never from per-batch position
tensors: causality/windowing is a property of sequence order, so the mask is
a batch-free [1, Sq, Sk] — an early dry-run showed GSPMD replicating a
[B, Sq, Sk] f32 position-derived mask on every device (~1.2 TB of traffic
per layer at train_4k), which this layout eliminates.  RoPE still uses the
real (possibly per-batch, possibly M-RoPE) position tensors.

Long sequences use a lax.scan over KV chunks with online softmax (flash-style
numerics) so 32k prefill never materializes an S x S score matrix.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, softcap

NEG_INF = -2.0e38


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def _mask_bias(q_rank, k_rank, causal: bool, window: Optional[int],
               k_valid=None):
    """[1, Sq, Sk] additive bias in f32 from sequence ranks [1, S]."""
    d = q_rank[:, :, None] - k_rank[:, None, :]
    m = jnp.ones(d.shape, dtype=bool)
    if causal:
        m = m & (d >= 0)
    if window is not None:
        m = m & (d < window)
    if k_valid is not None:
        m = m & k_valid[:, None, :]
    return jnp.where(m, 0.0, NEG_INF)


def _attend_dense(q, k, v, bias, scale, cap, scores_f32: bool = True):
    """q: [B,Sq,H,hd]; k/v: [B,Sk,KV,hd]; bias: [1,Sq,Sk].

    scores_f32=False materializes scores/weights in bf16 (max/sum reductions
    still accumulate in f32 via fused convert-reduce) — halves the dominant
    HBM term of 4k training; the full fix is a fused flash kernel whose
    scores never leave VMEM (see EXPERIMENTS §Perf)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    qg = q.reshape(b, sq, kv, rep, hd)
    sdt = jnp.float32 if scores_f32 else jnp.bfloat16
    logits = jnp.einsum("bqkrh,bskh->bkrqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = (softcap(logits, cap) + bias[:, None, None, :, :]).astype(sdt)
    m = jnp.max(logits.astype(jnp.float32), axis=-1, keepdims=True)
    p = jnp.exp(logits.astype(jnp.float32) - m).astype(sdt)
    den = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
    out = jnp.einsum("bkrqs,bskh->bqkrh", p, v,
                     preferred_element_type=jnp.float32)
    out = out / den.reshape(b, kv, rep, sq, 1).transpose(0, 3, 1, 2, 4)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _attend_chunked(q, k, v, q_rank, k_rank, causal, window, scale, cap,
                    chunk: int = 1024, k_valid=None):
    """Online-softmax over KV chunks — O(S·chunk) memory for long prefill.
    q_rank: [1, Sq]; k_rank: [1, Sk]; k_valid: [1, Sk] or None."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kv = k.shape[2]
    rep = h // kv
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    valid = (k_valid if k_valid is not None
             else jnp.ones((1, sk), dtype=bool))
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_rank = jnp.pad(k_rank, ((0, 0), (0, pad)), constant_values=-1)
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    kc = k.reshape(b, n_chunks, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    pc = k_rank.reshape(1, n_chunks, chunk).transpose(1, 0, 2)
    mc = valid.reshape(1, n_chunks, chunk).transpose(1, 0, 2)
    qg = q.reshape(b, sq, kv, rep, hd).astype(jnp.float32)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        kci, vci, pci, mci = xs
        bias = _mask_bias(q_rank, pci, causal, window, mci)  # [1,Sq,C]
        logits = jnp.einsum("bqkrh,bckh->bkrqc", qg,
                            kci.astype(jnp.float32)) * scale
        logits = softcap(logits, cap) + bias[:, None, None, :, :]
        m_new = jnp.maximum(m_prev, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkrqc,bckh->bkrqh", p, vci.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (jnp.full((b, kv, rep, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, kv, rep, sq), jnp.float32),
            jnp.zeros((b, kv, rep, sq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (kc, vc, pc, mc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def attention(p: dict, x: jnp.ndarray, cfg, spec, positions,
              *, causal: bool = True, cache: Optional[dict] = None,
              cache_index=None, kv_source: Optional[jnp.ndarray] = None,
              chunked_threshold: Optional[int] = None):
    """Full attention sublayer (projections + rope + attend + out-proj).

    cache: {"k","v"} [B, S_max, KV, hd] for self-attn prefill/decode, or
    {"xk","xv"} precomputed encoder KV for cross-attention decode.
    kv_source: encoder states for cross-attention prefill/train.
    Returns (out, new_cache).
    """
    hd = cfg.resolved_head_dim
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    b, s, _ = x.shape
    scale = hd ** -0.5
    if chunked_threshold is None:
        chunked_threshold = getattr(cfg, "attn_chunk_threshold", 8192)

    q = _split_heads(x @ p["wq"], h, hd)
    if "bq" in p:
        q = q + p["bq"].reshape(1, 1, h, hd)
    cross = kv_source is not None or (cache is not None and "xk" in cache)
    if cross and cache is not None and "xk" in cache:
        k, v = cache["xk"], cache["xv"]
        new_cache = {"xk": k, "xv": v}
    else:
        src = kv_source if cross else x
        k = _split_heads(src @ p["wk"], kvh, hd)
        v = _split_heads(src @ p["wv"], kvh, hd)
        if "bk" in p:
            k = k + p["bk"].reshape(1, 1, kvh, hd)
            v = v + p["bv"].reshape(1, 1, kvh, hd)
        if not cross and cfg.pos in ("rope", "mrope"):
            q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        if cross:
            new_cache = {"xk": k, "xv": v}
        elif cache is not None:   # write into the ring buffer
            idx = cache_index
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
            new_cache = {"k": ck, "v": cv}
            k, v = ck, cv
        else:
            new_cache = None

    # ---- batch-free sequence-rank masks ----
    sk = k.shape[1]
    k_rank = jnp.arange(sk, dtype=jnp.int32)[None]
    if cross:
        q_rank = jnp.zeros((1, s), jnp.int32)
        k_valid = None
        causal_, window_ = False, None
    elif cache is not None and "k" in new_cache:
        q_rank = (cache_index + jnp.arange(s, dtype=jnp.int32))[None]
        k_valid = (k_rank <= cache_index + s - 1)
        causal_, window_ = causal, spec.window
    else:
        q_rank = jnp.arange(s, dtype=jnp.int32)[None]
        k_valid = None
        causal_, window_ = causal, spec.window

    if sk > chunked_threshold and s > 1:
        out = _attend_chunked(q, k, v, q_rank, k_rank, causal_, window_,
                              scale, cfg.attn_softcap, k_valid=k_valid)
    else:
        bias = _mask_bias(q_rank, k_rank, causal_, window_, k_valid)
        out = _attend_dense(q, k, v, bias, scale, cfg.attn_softcap,
                            scores_f32=getattr(cfg, "attn_scores_f32", True))

    out = out.reshape(b, s, h * hd) @ p["wo"]
    return out, new_cache
