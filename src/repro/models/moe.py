"""Mixture-of-Experts FFN: top-k routing with capacity, sort-based dispatch.

Design (TPU-adapted): instead of the GShard one-hot dispatch einsum — whose
[tokens, E, C] mask dominates memory at 32k sequence lengths — tokens are
*sorted* by expert assignment and gathered into a dense [E, C, d] buffer
(sort + take are XLA-native and compile to decent TPU code).  Tokens beyond
an expert's capacity are dropped (their weight mass is renormalized away),
matching Switch/GShard capacity semantics.  Expert weights shard over the
``model`` axis (EP); the gather/scatter stays local to the data shard.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import activation


def _expert_ffn(we: dict, xe: jnp.ndarray, cfg) -> jnp.ndarray:
    """xe: [E, C, d] -> [E, C, d] through per-expert (gated) FFN."""
    if cfg.glu:
        g = activation(jnp.einsum("ecd,edf->ecf", xe, we["wg"]), cfg.act)
        u = jnp.einsum("ecd,edf->ecf", xe, we["wu"])
        return jnp.einsum("ecf,efd->ecd", g * u, we["wd"])
    u = activation(jnp.einsum("ecd,edf->ecf", xe, we["wu"]), cfg.act)
    return jnp.einsum("ecf,efd->ecd", u, we["wd"])


def moe_ffn(p: dict, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d].  Returns (out, aux_loss).

    With ``moe_grouped_dispatch`` (default) each batch element is its own
    routing group (GShard): the sort/gather indices never cross the
    data-sharded batch dim, so dispatch stays shard-local — the ungrouped
    variant showed ~65 GB/layer of dispatch-gather all-reduces in the
    dry-run (EXPERIMENTS §Perf iteration 2).
    """
    if getattr(cfg, "moe_grouped_dispatch", False) and x.shape[0] > 1:
        grouped = jax.vmap(lambda xb: _moe_tokens(p, xb[None], cfg))
        out, aux = grouped(x)
        return out[:, 0], aux.mean()
    return _moe_tokens(p, x, cfg)


def _moe_tokens(p: dict, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray,
                                                       jnp.ndarray]:
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # [T, E]
    gate, choice = jax.lax.top_k(probs, m.top_k)                  # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch eq. 4)
    density = jnp.mean(
        jax.nn.one_hot(choice[:, 0], m.num_experts, dtype=jnp.float32), 0)
    aux = m.num_experts * jnp.sum(density * probs.mean(0))

    cap = int(max(1, round(t * m.top_k * m.capacity_factor / m.num_experts)))
    flat_e = choice.reshape(-1)                                   # [T*k]
    flat_t = jnp.repeat(jnp.arange(t), m.top_k)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e)                                   # stable
    se, st_, sg = flat_e[order], flat_t[order], flat_g[order]
    # position within expert = rank - start(expert)
    start = jnp.searchsorted(se, jnp.arange(m.num_experts))
    pos = jnp.arange(t * m.top_k) - start[se]
    keep = pos < cap
    sentinel = m.num_experts * cap  # one-past-end row: dropped tokens
    slot = jnp.where(keep, se * cap + pos, sentinel)

    # scatter token ids into expert slots, gather activations
    src = jnp.full((m.num_experts * cap + 1,), t, dtype=jnp.int32)
    src = src.at[slot].set(st_.astype(jnp.int32), mode="drop")
    src = src[:-1]
    xe = jnp.take(jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)]), src,
                  axis=0).reshape(m.num_experts, cap, d)
    ye = _expert_ffn(p["experts"], xe, cfg).reshape(m.num_experts * cap, d)

    # combine back: each (token, k) slot reads its expert output
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)])
    out_flat = jnp.take(ye, jnp.where(keep, slot, m.num_experts * cap),
                        axis=0) * jnp.where(keep, sg, 0.0)[:, None].astype(ye.dtype)
    # unsort and sum the k contributions per token
    out = jnp.zeros((t, d), ye.dtype).at[st_].add(out_flat)
    if m.shared_expert:
        from repro.models.mlp import mlp as dense_mlp
        out = out + dense_mlp(p["shared"], xt, cfg)
    return out.reshape(b, s, d), aux
