"""Assigned input shapes and per-(arch x shape) input specs.

Four LM shape cells (the brief's assignment):
    train_4k     seq 4096,    global batch 256   -> train_step
    prefill_32k  seq 32768,   global batch 32    -> prefill_step
    decode_32k   seq 32768 KV, global batch 128  -> serve_step (1 new token)
    long_500k    seq 524288 KV, global batch 1   -> serve_step; only for
                 sub-quadratic archs (SSM/hybrid) — skips recorded per config.

``input_specs`` returns ShapeDtypeStructs (weak-type-correct, shardable, no
allocation) + PartitionSpecs for every input of the lowered step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.launch.mesh import dp_axes
from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq: int
    batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_is_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention architecture: 500k dense decode is "
                       "the quadratic regime this cell excludes (DESIGN.md "
                       "§Arch-applicability)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh):
    """(abstract_batch, batch_pspecs) for the model inputs of one cell."""
    dp = dp_axes(mesh)
    b = cell.batch
    s = 1 if cell.kind == "decode" else cell.seq
    dpb = dp if b % max(1, _axsize(mesh, dp)) == 0 else None
    bspec = dpb if b > 1 else None
    batch: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    if cfg.input_mode == "embeds":
        batch["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        specs["embeds"] = PS(bspec, None, None)
    else:
        batch["tokens"] = _sds((b, s), jnp.int32)
        specs["tokens"] = PS(bspec, None)
    if cfg.encoder_layers and cell.kind != "decode":
        batch["enc_embeds"] = _sds((b, cell.seq, cfg.d_model), jnp.bfloat16)
        specs["enc_embeds"] = PS(bspec, None, None)
    if cfg.pos == "mrope":
        pos_shape = (3, b, s)
        batch["positions"] = _sds(pos_shape, jnp.int32)
        specs["positions"] = PS(None, bspec, None)
    elif cell.kind == "decode":
        batch["positions"] = _sds((b, s), jnp.int32)
        specs["positions"] = PS(bspec, None)
    if cell.kind == "train":
        batch["labels"] = _sds((b, s), jnp.int32)
        specs["labels"] = PS(bspec, None)
    if cell.kind == "decode":
        batch["cache_index"] = _sds((), jnp.int32)
        specs["cache_index"] = PS()
    return batch, specs


def cache_specs(cfg: ModelConfig, cell: ShapeCell, mesh):
    s_enc = cell.seq if cfg.encoder_layers else None
    abstract = T.init_cache(cfg, cell.batch, cell.seq, s_enc, abstract=True)
    pspecs = T.cache_pspecs(cfg, mesh, cell.batch, cell.seq, s_enc)
    return abstract, pspecs


def _axsize(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes[a]
    return n


def microbatches(cfg: ModelConfig, cell: ShapeCell, mesh) -> int:
    """Gradient-accumulation factor: bound live activation memory to roughly
    one sequence per data shard per microbatch for the big configs."""
    if cell.kind != "train":
        return 1
    dp = _axsize(mesh, dp_axes(mesh))
    per_shard = max(1, cell.batch // dp)
    if cfg.n_micro_override:
        return min(per_shard, cfg.n_micro_override)
    if cfg.param_count() > 3e10:
        return min(per_shard, 8)
    if cfg.param_count() > 5e9:
        return min(per_shard, 2)
    return 1
