"""Data pipeline: stateless, step-indexed, restart-exact.

``SyntheticLMData.batch_at(step)`` is a pure function of (seed, step,
host_id) — after a failure/restart, resuming at step k replays exactly the
batch the crashed run would have seen (no iterator state to checkpoint).
At multi-host scale each host generates only its shard (host_id keys the
stream), which is the standard deterministic-data-order contract.

``TCQRequestStream`` generates temporal k-core query workloads for the
serving driver/benchmarks (windows with controllable span/valid-rate).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    input_mode: str = "tokens"       # tokens | embeds
    d_model: int = 0                 # for embeds mode
    encoder: bool = False
    mrope: bool = False

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        b = self.batch // self.n_hosts
        out: Dict[str, np.ndarray] = {}
        toks = rng.integers(0, self.vocab, (b, self.seq + 1),
                            dtype=np.int64).astype(np.int32)
        if self.input_mode == "embeds":
            out["embeds"] = rng.normal(
                0, 0.02, (b, self.seq, self.d_model)).astype(np.float32)
        else:
            out["tokens"] = toks[:, :-1]
        out["labels"] = toks[:, 1:]
        if self.encoder:
            out["enc_embeds"] = rng.normal(
                0, 0.02, (b, self.seq, self.d_model)).astype(np.float32)
        if self.mrope:
            pos = np.broadcast_to(np.arange(self.seq, dtype=np.int32),
                                  (3, b, self.seq)).copy()
            out["positions"] = pos
        return out


@dataclasses.dataclass
class TCQRequestStream:
    """Query workload: (k, ts, te) windows over a graph's time span."""
    t_min: int
    t_max: int
    k: int = 2
    span: int = 3 * 86_400
    seed: int = 0

    def requests(self, n: int, start: int = 0):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, start]))
        span_total = max(1, self.t_max - self.t_min - self.span)
        for i in range(n):
            ts = int(self.t_min + rng.integers(0, span_total))
            yield {"id": start + i, "k": self.k, "ts": ts,
                   "te": ts + self.span}

    def open_loop(self, n: int, qps: float, start: int = 0):
        """Open-loop arrival process: the same request stream, each tagged
        with an ``arrive_s`` offset (seconds from t=0) drawn from a seeded
        exponential inter-arrival at rate ``qps`` — the serving driver
        submits a request once its wall clock passes ``arrive_s``,
        independent of service completions (so queueing is visible)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, start, 1]))
        clock = 0.0
        for r in self.requests(n, start):
            clock += float(rng.exponential(1.0 / max(qps, 1e-9)))
            r["arrive_s"] = clock
            yield r
