"""Dynamic-graph feed (paper §6.1): batched edge arrival over an ArrayTEL.

The paper appends single edges to its linked-list TEL in O(1).  The array
equivalent is a stream of timestamp-ordered batches; each ``push`` is an
incremental sorted-run merge-append (`TemporalGraph.add_edges`,
O(E + B log B)) producing a *new epoch* — an immutable snapshot.  In-flight
queries pinned to an older epoch keep their snapshot; subscribers (the
streaming ``TCQService`` / ``TCQEngine.update_graph``) install the new
epoch for everything admitted afterwards.
"""

from __future__ import annotations

from typing import Callable, Iterator, Optional, Tuple

import numpy as np

from repro.core.graph import TemporalGraph


class EdgeStream:
    """Replays a temporal graph as arrival batches, or accepts live pushes."""

    def __init__(self, initial: Optional[TemporalGraph] = None):
        self.graph = initial if initial is not None else TemporalGraph.from_edges(
            np.zeros(0), np.zeros(0), np.zeros(0), 0)
        self._subscribers: list[Callable[[TemporalGraph], None]] = []

    def subscribe(self, fn: Callable[[TemporalGraph], None]) -> None:
        self._subscribers.append(fn)

    def push(self, u, v, t) -> TemporalGraph:
        """Merge-append one arrival batch; notify subscribers of the new
        epoch.  Returns the new snapshot (the old one stays valid)."""
        self.graph = self.graph.add_edges(u, v, t)
        for fn in self._subscribers:
            fn(self.graph)
        return self.graph

    @staticmethod
    def replay(graph: TemporalGraph, n_batches: int
               ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Split a graph into n timestamp-ordered arrival batches."""
        order = np.argsort(graph.t, kind="stable")
        for chunk in np.array_split(order, n_batches):
            if chunk.size:
                yield graph.src[chunk], graph.dst[chunk], graph.t[chunk]
