"""Perf-hillclimb driver: lower one cell with ModelConfig overrides and log
the roofline delta vs a named baseline record.

    PYTHONPATH=src python -m benchmarks.perf_lower \
        --arch jamba-1.5-large-398b --shape train_4k \
        --set mamba_scan=assoc --tag jamba_assoc
"""

import argparse
import ast
import json
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--set", action="append", default=[],
                    help="field=value ModelConfig override (repeatable)")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--baseline", default="",
                    help="path of a baseline record to diff against")
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell

    overrides = {}
    for kv in getattr(args, "set"):
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v
    rec, _ = lower_cell(args.arch, args.shape, args.mesh == "multi",
                        overrides=overrides)
    rec["overrides"] = overrides
    out = os.path.join(os.path.dirname(__file__), "results", "perf",
                       args.tag + ".json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    rl = rec["roofline"]
    print(f"[perf] {args.tag}: t_comp={rl['t_compute_s']:.3f} "
          f"t_mem={rl['t_memory_s']:.3f} t_coll={rl['t_collective_s']:.3f} "
          f"dom={rl['dominant']} frac={rl.get('roofline_fraction', 0):.5f}")
    if args.baseline and os.path.exists(args.baseline):
        base = json.load(open(args.baseline))["roofline"]
        for k in ("t_compute_s", "t_memory_s", "t_collective_s",
                  "roofline_fraction"):
            if base.get(k):
                print(f"  {k:18s} {base[k]:10.4f} -> {rl[k]:10.4f} "
                      f"({rl[k] / base[k]:.3f}x)")


if __name__ == "__main__":
    main()
