"""Dispatching wrapper + cost model for the fused wave-peel kernel.

``make_fused_wave_step`` does the host-side analysis once per TEL
(segment-bound tables from the canonical sort, 128-lane padding, VMEM
budgeting) and returns a jitted ``step(alive, ts, te, k, h) ->
StepResult`` closure, or ``None`` when the TEL's VMEM working set
exceeds the budget — callers (``core.wave.make_wave_step_fn``) fall
back to the XLA composite, which is exactly the regime where the
engine's window truncation should have kept E small in the first place.

``fused_step_cost`` is the structural HBM/FLOP model used by
``benchmarks/bench_wave.py`` and ``benchmarks/roofline.py``: the fused
step's HBM bytes are *iteration-independent* (tables once per W-tile +
the alive slab + outputs), which is the whole point vs the unfused
chain's per-iteration [W, E] round-trips.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segdeg.ops import on_tpu
from repro.kernels.wave_peel.kernel import segment_bounds, wave_peel_pallas

_I32_MIN = np.iinfo(np.int32).min

# Per-core VMEM is ~16 MB on current TPUs; leave headroom for Mosaic's
# own temporaries (the loop carries alive + ea, plus one cumsum buffer).
DEFAULT_VMEM_BUDGET = 12 << 20


def _align(n: int, m: int) -> int:
    return -(-int(n) // m) * m


def fused_step_vmem_bytes(num_edges: int, num_pairs: int, num_halfpairs: int,
                          v32: int, w_tile: int) -> int:
    """Worst-case VMEM working set of one grid program (bytes)."""
    e = _align(num_edges, 128)
    hp = _align(num_halfpairs, 128)
    p = _align(num_pairs, 128)
    tables = 4 * (3 * e + hp + 2 * p + 2 * v32)
    # per-lane live arrays: win + ea + carry copy (bool ~ int8) and the
    # int32 cumsum / paircnt / contrib / deg intermediates
    per_lane = 3 * e + 4 * (e + 2 * p + hp + 2 * v32)
    return tables + w_tile * per_lane


def fused_step_cost(num_edges: int, num_pairs: int, num_halfpairs: int,
                    num_vertices: int, wave: int, *, w_tile: int = 8,
                    iters: int = 1) -> dict:
    """Structural cost model of one fused step (per-device).

    HBM bytes are iteration-independent: each W-tile program streams the
    TEL + band tables once and the lane slab in/out once; every fixpoint
    intermediate stays in VMEM.  FLOPs scale with ``iters`` (compares,
    cumsums and gathers counted as 1 op/element).
    """
    v32 = _align(max(num_vertices, 1), 32)
    e = _align(num_edges, 128)
    hp = _align(num_halfpairs, 128)
    p = _align(num_pairs, 128)
    w_pad = _align(max(wave, 1), w_tile)
    tiles = w_pad // w_tile
    table_bytes = 4.0 * (3 * e + hp + 2 * p + 2 * v32)
    lane_bytes = float(w_pad) * (2 * v32            # alive in + out (bool)
                                 + 4 * (v32 // 32)  # packed words
                                 + 4 * 3) + tiles * 4.0  # lo/hi/ne + iters
    scalar_bytes = 4.0 * 4 * w_pad                  # ts/te/k/h prefetch
    flops_per_iter = float(w_pad) * (
        5.0 * e            # window compare x2, two gathers, 3-way and
        + 2.0 * e          # edge-axis cumsum + boundary diffs
        + 3.0 * p          # pair-count gather/compare/threshold
        + 3.0 * hp         # contrib gather + halfpair cumsum
        + 3.0 * v32)       # degree diff + k compare + and
    return {
        "bytes_per_step": tiles * table_bytes + lane_bytes + scalar_bytes,
        "bytes_per_iter_hbm": 0.0,
        "flops_per_iter": flops_per_iter,
        "flops_per_step": flops_per_iter * max(int(iters), 1),
        "vmem_bytes": fused_step_vmem_bytes(num_edges, num_pairs,
                                            num_halfpairs, v32, w_tile),
    }


def make_fused_wave_step(tel, num_vertices: int, *, w_tile: int = 8,
                         interpret: Optional[bool] = None,
                         donate: bool = False,
                         vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET):
    """Build the fused Pallas step for one (capacity-shaped) DeviceTEL.

    Returns ``step(alive [W, V] bool, ts, te, k, h) -> StepResult`` (the
    ``core.wave`` result type, bit-identical to the composite), or
    ``None`` when the per-program VMEM working set exceeds the budget.
    ``interpret=None`` auto-resolves: compiled on TPU, interpret mode
    elsewhere (the CPU correctness gates).
    """
    interp = (not on_tpu()) if interpret is None else bool(interpret)
    v = int(num_vertices)
    v32 = _align(max(v, 1), 32)
    e = int(tel.t.shape[0])
    p = int(tel.pair_u.shape[0])
    hp = int(tel.hp_src.shape[0])
    if not interp and fused_step_vmem_bytes(e, p, hp, v32, w_tile) > \
            int(vmem_budget_bytes):
        return None

    # host-side band analysis (once per TEL; the canonical sort makes
    # every segment a contiguous run — no scatter on device)
    pair_id = np.asarray(tel.pair_id)
    hp_src = np.asarray(tel.hp_src)
    ps, pe = segment_bounds(pair_id, p)
    vs, ve = segment_bounds(hp_src, v32)

    e_pad = _align(max(e, 1), 128)
    hp_pad = _align(max(hp, 1), 128)
    p_pad = _align(max(p, 1), 128)

    def pad_to(a, n, fill=0):
        a = np.asarray(a)
        out = np.full(n, fill, dtype=np.int32)
        out[:a.shape[0]] = a
        return jnp.asarray(out[None, :])

    # sentinel-padded tails: t = int32 min fails every window test, so
    # padded edges are dead; padded pair/vertex slots get empty ranges
    t2 = pad_to(tel.t, e_pad, _I32_MIN)
    src2 = pad_to(tel.src, e_pad)
    dst2 = pad_to(tel.dst, e_pad)
    hpp2 = pad_to(tel.hp_pair, hp_pad)
    ps2 = pad_to(ps, p_pad)
    pe2 = pad_to(pe, p_pad)
    vs2 = jnp.asarray(vs[None, :])
    ve2 = jnp.asarray(ve[None, :])

    def _step(alive, ts, te, k, h):
        from repro.core.wave import StepResult

        w = alive.shape[0]
        w_pad = _align(max(w, 1), w_tile)
        # padding lanes carry the empty window (ts=0 > te=-1) and k=h=1
        # with an all-dead mask: they converge on iteration 1 and never
        # inflate the per-tile iteration count
        def lanes(x, fill):
            x = jnp.broadcast_to(jnp.asarray(x, jnp.int32), (w,))
            return jnp.pad(x, (0, w_pad - w), constant_values=fill)

        alive_p = jnp.pad(alive, ((0, w_pad - w), (0, v32 - v)))
        a_out, packed, lo, hi, ne, itrs = wave_peel_pallas(
            lanes(ts, 0), lanes(te, -1), lanes(k, 1), lanes(h, 1),
            t2, src2, dst2, hpp2, ps2, pe2, vs2, ve2, alive_p,
            w_tile=w_tile, interpret=interp)
        return StepResult(
            a_out[:w, :v],
            jax.lax.bitcast_convert_type(packed, jnp.uint32)[:w],
            lo[:w, 0], hi[:w, 0], ne[:w, 0], jnp.max(itrs))

    jitted = jax.jit(_step, donate_argnums=(0,)) if donate \
        else jax.jit(_step)

    @functools.wraps(_step)
    def step(alive, ts, te, k, h):
        return jitted(alive, ts, te, k, h)

    step.backend = "pallas"
    step.interpret = interp
    step.w_tile = w_tile
    step.cost = fused_step_cost(e, p, hp, v, wave=w_tile, w_tile=w_tile)
    # operand census for perf_lower's structural assert: nothing
    # [W, E]-shaped ever crosses HBM on this path
    step.operand_shapes = [tuple(x.shape) for x in
                           (t2, src2, dst2, hpp2, ps2, pe2, vs2, ve2)]
    return step
