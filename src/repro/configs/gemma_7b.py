"""Gemma 7B [arXiv:2403.08295] — GeGLU, head_dim=256, embed scaling."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24_576, vocab=256_000,
    act="gelu", glu=True, pos="rope", embed_scale=True,
    tie_embeddings=True,
    max_seq=32_768,
    notes="GeGLU; 256k vocab stresses the vocab-sharded embed/unembed",
)
