"""Roofline report: aggregates the dry-run records into the EXPERIMENTS.md
§Roofline table (per arch x shape x mesh: three terms, dominant bottleneck,
useful-compute ratio, roofline fraction + a one-line 'what moves it')."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN = os.path.join(os.path.dirname(__file__), "results", "dryrun")
KERNEL = os.path.join(os.path.dirname(__file__), "results",
                      "bench_kernel.json")

_MOVES = {
    ("memory", "train"): "cut softmax/logit f32 traffic (flash-style "
                         "attention, bf16 logits, more microbatching)",
    ("memory", "prefill"): "chunked attention already on; next: fuse KV "
                           "write + rope (Pallas), bf16 accumulators",
    ("memory", "decode"): "KV cache streaming dominates: quantize KV to "
                          "int8 or shrink replication of KV heads",
    ("memory", "tcq"): "fuse window mask + gather into the banded-segsum "
                       "kernel; bitpack edge-activity",
    ("collective", "train"): "overlap FSDP all-gathers with compute; "
                             "reduce-scatter grads; int8 compression",
    ("collective", "decode"): "shrink the model-axis softmax combine "
                              "(flash-decoding partials)",
    ("collective", "tcq"): "rs_ag combine (bool alive all-gather) instead "
                           "of dense psum",
    ("compute", "train"): "already MXU-bound: raise MFU via larger "
                          "microbatch or fused kernels",
    ("compute", "tcq"): "narrow the one-hot band (smaller S_TILE) or more "
                        "lanes per step",
}


def load() -> List[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_row(r: dict) -> str:
    rl = r["roofline"]
    kind = r.get("kind", "?")
    dom = rl["dominant"]
    move = _MOVES.get((dom, "tcq" if kind == "tcq" else r["kind"]),
                      _MOVES.get((dom, "train"), ""))
    ratio = rl.get("useful_compute_ratio")
    frac = rl.get("roofline_fraction")
    name = r["arch"]
    if r.get("combine"):
        name += f"[{r['combine']}]"
    return ("| {n} | {s} | {m} | {tc:.4f} | {tm:.4f} | {tx:.4f} | {d} | "
            "{ur} | {rf} |").format(
        n=name, s=r["shape"], m=r["mesh"],
        tc=rl["t_compute_s"], tm=rl["t_memory_s"], tx=rl["t_collective_s"],
        d=dom,
        ur=f"{ratio:.2f}" if ratio else "-",
        rf=f"{frac:.4f}" if frac else "-")


def wave_step_report() -> None:
    """Fused wave-peel step as a fraction of peak (needs a prior
    ``bench_wave.run_kernel()`` run for benchmarks/results/
    bench_kernel.json).  Both lowerings are scored at TPU peaks — the
    cost numbers describe the lowering, not the host they were derived
    on — so the table answers "what fraction of the HBM roofline does
    the step sustain", not "how fast was the interpreter"."""
    if not os.path.exists(KERNEL):
        return
    with open(KERNEL) as f:
        rows = json.load(f)
    cost = next((r for r in rows if r.get("bench") == "fused_step_cost"),
                None)
    if cost is None:
        return
    from repro.launch.analysis import HBM_BW, PEAK_FLOPS

    print(f"\nfused wave-peel step (graph={cost['graph']} W={cost['wave']} "
          f"E={cost['num_edges']} iters={cost['iters']}):")
    print("| lowering | bytes/step | flops/step | t_mem(s) | t_comp(s) | "
          "bound | ai(flop/B) | frac_peak_flops |")
    print("|---|---|---|---|---|---|---|---|")
    for path in ("unfused", "fused"):
        b = float(cost[f"{path}_bytes_step"])
        fl = float(cost[f"{path}_flops_step"])
        t_mem = b / HBM_BW
        t_comp = fl / PEAK_FLOPS
        t = max(t_mem, t_comp, 1e-30)
        # both lowerings sit left of the machine-balance knee: the step
        # runs AT the HBM roofline, so "fraction of peak" is the compute
        # utilization that bound allows — raising arithmetic intensity
        # (fewer HBM bytes per op, i.e. fusion) is what moves it
        print(f"| {path} | {b:.3e} | {fl:.3e} | {t_mem:.2e} | "
              f"{t_comp:.2e} | {'mem' if t_mem >= t_comp else 'comp'} | "
              f"{fl / max(b, 1.0):.3f} | {t_comp / t:.3f} |")
    ratio = float(cost["bytes_ratio"])
    print(f"fused/unfused bytes per step: {ratio:.2e} "
          f"(per-iteration HBM bytes: "
          f"{float(cost['fused_bytes_per_iter_hbm']):.0f} fused vs "
          f"{float(cost['unfused_bytes_per_iter']):.3e} unfused)")


def main():
    recs = load()
    ok = [r for r in recs if not r.get("failed") and not r.get("skipped")]
    skipped = [r for r in recs if r.get("skipped")]
    failed = [r for r in recs if r.get("failed")]
    print("| arch | shape | mesh | t_comp(s) | t_mem(s) | t_coll(s) | "
          "dominant | useful | roofline_frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r.get("kind", ""), r["arch"],
                                       r["shape"], r["mesh"])):
        print(fmt_row(r))
    print(f"\n{len(ok)} cells ok, {len(skipped)} skipped (recorded), "
          f"{len(failed)} failed")
    for r in skipped:
        print(f"  skip: {r['arch']} x {r['shape']}: {r['reason'][:80]}")
    for r in failed:
        print(f"  FAIL: {r.get('arch')} x {r.get('shape')}")
    # dominant-term census (what the perf pass should attack)
    census: Dict[str, int] = {}
    for r in ok:
        census[r["roofline"]["dominant"]] = census.get(
            r["roofline"]["dominant"], 0) + 1
    print("\ndominant-term census:", census)
    worst = sorted((r for r in ok if r["roofline"].get("roofline_fraction")),
                   key=lambda r: r["roofline"]["roofline_fraction"])[:5]
    print("\nworst roofline fractions (hillclimb candidates):")
    for r in worst:
        print(f"  {r['arch']} x {r['shape']} x {r['mesh']}: "
              f"{r['roofline']['roofline_fraction']:.5f} "
              f"dom={r['roofline']['dominant']}")
    coll = sorted(ok, key=lambda r: -r["roofline"]["t_collective_s"])[:5]
    print("\nmost collective-bound:")
    for r in coll:
        print(f"  {r['arch']} x {r['shape']} x {r['mesh']}"
              f"{'[' + r['combine'] + ']' if r.get('combine') else ''}: "
              f"t_coll={r['roofline']['t_collective_s']:.3f}s")
    wave_step_report()


if __name__ == "__main__":
    main()
