"""Temporal Core Decomposition (TCD) — the paper's §3, vectorized for TPU.

The paper's TCD operation peels one minimum-degree vertex at a time off a
pointer TEL.  The TPU-native equivalent is *frontier peeling*: one fixpoint
iteration removes **all** vertices with fewer than k distinct alive
neighbours at once; ``lax.while_loop`` iterates to the fixpoint.  Correctness
is the classical k-core invariance to peel order, plus the paper's Theorem 1:
peeling may warm-start from **any** sandwiched supergraph, which is what makes
the decremental enumeration (and our batched/ distributed variants) valid.

Degree semantics are the paper's: the number of distinct neighbour *vertices*
(not parallel edges) — realized as a two-level segment reduction
edges -> pairs -> vertices.  The pair level also gives the link-strength
extension (§6.2) for free: a pair counts only with >= h alive parallel edges.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.graph import DeviceTEL

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min


class TCDResult(NamedTuple):
    alive: jnp.ndarray    # [V] bool — vertices of T^k_[ts,te]
    tti_lo: jnp.ndarray   # scalar int32 (I32_MAX when core is empty)
    tti_hi: jnp.ndarray   # scalar int32 (I32_MIN when core is empty)
    n_edges: jnp.ndarray  # scalar int32
    n_verts: jnp.ndarray  # scalar int32


def edge_activity(tel: DeviceTEL, alive: jnp.ndarray, ts, te) -> jnp.ndarray:
    """[E] bool: edge is inside the window and both endpoints are alive."""
    win = (tel.t >= ts) & (tel.t <= te)
    return win & alive[tel.src] & alive[tel.dst]


def degrees(tel: DeviceTEL, ea: jnp.ndarray, h, *, num_vertices: int) -> jnp.ndarray:
    """[V] int32 distinct-neighbour degrees from edge activity.

    Two sorted segment reductions (the Pallas `banded_segsum` kernel replaces
    these on TPU; this is the pure-jnp reference path used on CPU).
    """
    paircnt = jax.ops.segment_sum(
        ea.astype(jnp.int32), tel.pair_id,
        num_segments=tel.num_pairs, indices_are_sorted=True,
    )
    pairact = (paircnt >= h).astype(jnp.int32)
    deg = jax.ops.segment_sum(
        pairact[tel.hp_pair], tel.hp_src,
        num_segments=num_vertices, indices_are_sorted=True,
    )
    return deg


@functools.partial(jax.jit, static_argnames=("num_vertices", "degree_fn"))
def tcd(tel: DeviceTEL, alive: jnp.ndarray, ts, te, k, h,
        *, num_vertices: int, degree_fn=None) -> TCDResult:
    """One TCD operation: truncate to [ts, te], peel to the k-core fixpoint.

    ``alive`` may be any superset core's vertex mask (Theorem 1) — all-ones
    for a cold start.  ts/te/k/h are dynamic scalars: a single compiled
    program serves every cell of the enumeration schedule.
    """
    dfn = degree_fn or degrees

    # edge activity rides in the carry: the final loop iteration observes
    # new == cur, so the ea it computed is exactly ea(fixpoint) and the
    # post-loop edge pass is saved (one full [E] evaluation per cell)
    def body(state):
        cur, _, _ = state
        ea = edge_activity(tel, cur, ts, te)
        deg = dfn(tel, ea, h, num_vertices=num_vertices)
        new = cur & (deg >= k)
        return new, ea, jnp.any(new != cur)

    def cond(state):
        return state[2]

    ea0 = jnp.zeros(tel.t.shape, dtype=bool)
    alive, ea, _ = lax.while_loop(cond, body, (alive, ea0, jnp.bool_(True)))
    n_edges = jnp.sum(ea, dtype=jnp.int32)
    # empty-fill sentinels must sit outside the timestamp range in BOTH
    # directions (-1 would clamp tti_hi for cores whose edges all have
    # t < -1 — timestamps may be arbitrary ints)
    tti_lo = jnp.min(jnp.where(ea, tel.t, _I32_MAX))
    tti_hi = jnp.max(jnp.where(ea, tel.t, _I32_MIN))
    # at the fixpoint every alive vertex has degree >= k (>= 1), so the
    # vertex count needs no extra reduction pass
    n_verts = jnp.sum(alive, dtype=jnp.int32)
    return TCDResult(alive, tti_lo, tti_hi, n_edges, n_verts)


@functools.partial(jax.jit, static_argnames=("num_vertices", "degree_fn"))
def tcd_batch(tel: DeviceTEL, alive: jnp.ndarray, ts, te, k, h,
              *, num_vertices: int, degree_fn=None) -> TCDResult:
    """Batched (wave-mode) TCD: Q independent cells peeled in lockstep.

    alive: [Q, V]; ts/te: [Q].  This is the beyond-paper engine: the degree
    reduction becomes a (Q x E)·(E x V) contraction that the MXU can eat.
    ``lax.while_loop`` under vmap runs until every lane converges; converged
    lanes are fixpoints so extra iterations are no-ops.
    """
    fn = functools.partial(
        tcd, tel, num_vertices=num_vertices, degree_fn=degree_fn)
    return jax.vmap(lambda a, s, e: fn(a, s, e, k, h))(alive, ts, te)


def coreness(tel: DeviceTEL, ts, te, *, num_vertices: int, k_max: int = 64):
    """Per-vertex coreness over a window — core decomposition by bisection on
    the shared `tcd` program (used by the PHC-index baseline and analytics)."""
    alive = jnp.ones((num_vertices,), dtype=bool)
    core = jnp.zeros((num_vertices,), dtype=jnp.int32)

    def body(k, state):
        alive, core = state
        res = tcd(tel, alive, ts, te, k, 1, num_vertices=num_vertices)
        core = jnp.where(res.alive, k, core)
        return res.alive, core

    alive, core = lax.fori_loop(1, k_max + 1, body, (alive, core))
    return core
