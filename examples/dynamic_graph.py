"""Dynamic temporal graph (paper §6.1 + §7.4 case-study flavor): stream
edge batches into the TEL and watch a community grow across re-queries —
the bursting-community analysis of the paper's Fig. 15.

Run:  PYTHONPATH=src python examples/dynamic_graph.py
"""

import numpy as np

from repro.core import TCQEngine
from repro.graphs import EdgeStream, planted_cores


def main():
    g = planted_cores(num_vertices=80, k=3, n_cliques=5, clique_size=7,
                      time_span=60, noise_edges=150, seed=13)
    stream = EdgeStream()
    print("streaming the graph in 5 arrival batches; querying after each\n")
    prev_ttis = set()
    for i, (u, v, t) in enumerate(EdgeStream.replay(g, 5)):
        stream.push(u, v, t)
        cur = stream.graph
        eng = TCQEngine(cur)
        res = eng.query(3, 1, 60)
        new = set(c.tti for c in res.cores) - prev_ttis
        prev_ttis |= new
        print(f"batch {i+1}: |E|={cur.num_edges:5d} -> {len(res):3d} cores "
              f"({len(new)} new)")
        # growth analysis: nested cores = community expansion (Fig. 15)
        chains = 0
        by_tti = res.by_tti()
        for c in res.cores:
            for c2 in res.cores:
                if (c2.tti[0] <= c.tti[0] and c.tti[1] <= c2.tti[1]
                        and c.n_vertices < c2.n_vertices
                        and set(c.vertices).issubset(set(c2.vertices))):
                    chains += 1
                    break
        print(f"          {chains} cores are nested inside a larger, "
              f"longer-lived core (growth chains)")
    top = sorted(res.cores, key=lambda c: -c.n_vertices)[:3]
    print("\nlargest communities at the end:")
    for c in top:
        print(f"  {c}")


if __name__ == "__main__":
    main()
