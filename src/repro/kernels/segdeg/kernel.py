"""Pallas TPU kernel: banded segment-sum as MXU one-hot matmuls.

The TCQ engine's hot spot is the two-level degree reduction
(edges -> pairs -> vertices) over a wave of Q query cells.  Segment ids are
SORTED (the ArrayTEL canonical order), so each input tile of N_TILE rows
touches a contiguous band of output segments.  The kernel exploits this:

  grid = (Q_tiles, S_tiles, K)      K = max input tiles per output band
  out[o] accumulates over the K consecutive grid steps (standard matmul
  k-loop pattern: same output block revisited consecutively), each step
  contracting a (S_TILE x N_TILE) one-hot "segment membership" matrix with a
  (N_TILE x Q_TILE) value tile on the MXU.

Per-output-tile input ranges (in_lo / in_hi, in block units) are computed
with two searchsorteds and passed via scalar prefetch so BlockSpec index
maps can chase the band.  K is data-dependent (hub vertices widen the
band); the ops.py wrapper derives it from the graph once at engine build
and falls back to XLA segment_sum above a cap.

Validated on CPU with interpret=True against ref.banded_segsum_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific grid spec (scalar prefetch); interpret mode also uses it
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _kernel(in_lo_ref, in_hi_ref, seg_ref, val_ref, out_ref, *,
            s_tile: int, n_tile: int):
    q, o, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # contribution is void when this k-step is past the band's end
    valid = (in_lo_ref[o] + j) <= in_hi_ref[o]
    rows = o * s_tile + jax.lax.broadcasted_iota(
        jnp.int32, (s_tile, n_tile), 0)
    segs = seg_ref[0, :]                         # [n_tile]
    onehot = (rows == segs[None, :]).astype(jnp.float32)
    contrib = jnp.dot(onehot, val_ref[...].astype(jnp.float32),
                      preferred_element_type=jnp.float32)
    out_ref[...] += jnp.where(valid, 1.0, 0.0) * contrib


@functools.partial(jax.jit, static_argnames=(
    "num_segments", "k_max", "s_tile", "n_tile", "q_tile", "interpret"))
def banded_segsum_pallas(values: jnp.ndarray, seg_ids: jnp.ndarray,
                         *, num_segments: int, k_max: int,
                         s_tile: int = 128, n_tile: int = 512,
                         q_tile: int = 128,
                         interpret: bool = True) -> jnp.ndarray:
    """values: [N, Q] (any float dtype); seg_ids: [N] int32 sorted; returns
    [num_segments, Q] f32.  k_max: max input tiles overlapping one output
    tile (host-derived from the static graph)."""
    n, qdim = values.shape
    n_pad = -(-n // n_tile) * n_tile
    q_pad = -(-qdim // q_tile) * q_tile
    s_pad = -(-num_segments // s_tile) * s_tile
    vals = jnp.pad(values.astype(jnp.float32),
                   ((0, n_pad - n), (0, q_pad - qdim)))
    # pad segment ids with an out-of-range id => zero one-hot rows
    segs = jnp.pad(seg_ids.astype(jnp.int32), (0, n_pad - n),
                   constant_values=jnp.int32(s_pad))
    segs2 = segs[None, :]                        # 2-D for TPU vmem tiling

    n_s_tiles = s_pad // s_tile
    starts = jnp.arange(n_s_tiles, dtype=jnp.int32) * s_tile
    in_lo = jnp.searchsorted(segs, starts, side="left") // n_tile
    last = jnp.searchsorted(segs, starts + s_tile, side="left") - 1
    in_hi = jnp.maximum(last, 0) // n_tile
    in_hi = jnp.maximum(in_hi, in_lo)
    in_lo = in_lo.astype(jnp.int32)
    in_hi = in_hi.astype(jnp.int32)

    grid = (q_pad // q_tile, n_s_tiles, k_max)
    n_in_tiles = n_pad // n_tile

    def seg_index(q, o, j, lo, hi):
        blk = jnp.minimum(lo[o] + j, n_in_tiles - 1)
        return (0, blk)

    def val_index(q, o, j, lo, hi):
        blk = jnp.minimum(lo[o] + j, n_in_tiles - 1)
        return (blk, q)

    def out_index(q, o, j, lo, hi):
        return (o, q)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n_tile), seg_index),
            pl.BlockSpec((n_tile, q_tile), val_index),
        ],
        out_specs=pl.BlockSpec((s_tile, q_tile), out_index),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, s_tile=s_tile, n_tile=n_tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s_pad, q_pad), jnp.float32),
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
    )(in_lo, in_hi, segs2, vals)
    return out[:num_segments, :qdim]


def required_k_max(seg_ids, num_segments: int, s_tile: int = 128,
                   n_tile: int = 512) -> int:
    """Host-side: max input tiles overlapping any output tile (static per
    graph, used to size the kernel grid)."""
    import numpy as np

    segs = np.asarray(seg_ids)
    n_s_tiles = -(-max(num_segments, 1) // s_tile)
    starts = np.arange(n_s_tiles) * s_tile
    lo = np.searchsorted(segs, starts, side="left") // n_tile
    last = np.maximum(np.searchsorted(segs, starts + s_tile, "left") - 1, 0)
    hi = np.maximum(last // n_tile, lo)
    return int(np.max(hi - lo + 1)) if n_s_tiles else 1
