"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --steps 50 \
        [--smoke] [--fail-at 20] [--microbatches 4] [--ckpt DIR]

On this CPU container --smoke (reduced config, host mesh) is the runnable
path; without it the launcher targets the production 16x16 mesh (real TPU
slices: one process per host, jax.distributed.initialize upstream of this).
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.data import SyntheticLMData
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.runtime import FaultInjector, Trainer, TrainerConfig

    if args.smoke:
        cfg = get_smoke_config(args.arch)
        mesh = make_host_mesh()
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    data = SyntheticLMData(
        vocab=cfg.vocab, batch=args.batch, seq=args.seq, seed=0,
        input_mode=cfg.input_mode, d_model=cfg.d_model,
        encoder=cfg.encoder_layers > 0, mrope=cfg.pos == "mrope")
    injector = FaultInjector(
        fail_at={args.fail_at: "cli-injected failure"}
        if args.fail_at >= 0 else {})
    tr = Trainer(cfg, mesh, data,
                 TrainerConfig(steps=args.steps,
                               ckpt_every=args.ckpt_every,
                               ckpt_dir=args.ckpt, lr=args.lr),
                 injector=injector)
    out = tr.run()
    print(f"[train] arch={args.arch} {out}")


if __name__ == "__main__":
    main()
