"""TTI-keyed core-result cache with incremental epoch invalidation.

The paper's Property 2 makes the Tightest Time Interval a canonical
identity: for a fixed graph snapshot and (k, h), two windows with the same
TTI induce the *same* (k, h)-core subgraph.  That turns the TTI into a
content-addressable key — a peeled core can be stored once per
``(epoch, k, h, TTI)`` and served to every later window that tightens to
it, across requests.  This module is that store.

Two index layers mirror the two halves of Property 2:

* **cores** — ``(epoch, k, h, lo, hi) -> (packed uint32 vertex bitmask,
  n_edges)``.  One entry per distinct core subgraph; payload bytes are
  bounded by a size-capped LRU (the PR 1 pack format keeps a core at
  V/8 bytes).
* **cells** — ``(epoch, k, h, ts, te) -> None | (lo, hi)``: the evaluated
  query window mapped to its TTI outcome (``None`` records a window with
  no (k, h)-core at all).  Cells are what admission-time lookup probes;
  they resolve a window without touching the device.

Lookups also exploit *dominance* (core monotonicity, paper Lemma 1): a
known cell ``(ts, te) -> (lo, hi)`` resolves any queried window
``(a, b)`` with ``ts <= a <= lo`` and ``hi <= b <= te`` — shrinking a
window while still containing its core's TTI cannot change the core.  An
empty cell resolves every sub-window the same way.  Note the converse
merge is *unsound*: two same-TTI windows cannot be unioned (edges between
the windows' slack regions can create a larger core), so entries stay
per-cell and dominance is a per-group linear scan.

Ingest never flushes.  ``advance_epoch(old, new, batch_lo, batch_hi)``
deletes only entries the appended batch can affect — a **cell** dies when
its *window* intersects the batch span (a new edge anywhere inside the
window can grow the core, even outside the old TTI); a **core payload**
dies when its *TTI* intersects (the payload is exactly ``core([lo, hi])``).
Survivors are re-keyed to the new epoch in place, so an append costs one
pass over the affected epoch's entries, not a cold cache.  The same
re-keying seam backs the engine's ``rebase_epoch``/``retire_epochs``.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Iterable, NamedTuple, Optional, Tuple

import numpy as np

_MISS = object()


class CacheHit(NamedTuple):
    """A resolved cell: its TTI and the cached core payload.

    ``n_edges == 0`` means the window has no (k, h)-core; then ``packed``
    is ``None`` and ``(tti_lo, tti_hi)`` echo the probed window.
    """

    tti_lo: int
    tti_hi: int
    n_edges: int
    packed: Optional[np.ndarray]   # uint32 LSB-first vertex bitmask row


class CoreCache:
    """Size-capped LRU of peeled cores, keyed ``(epoch, k, h, TTI)``.

    ``max_bytes`` bounds the packed-bitmask payload bytes; ``max_cells``
    bounds the (tiny, fixed-size) cell index.  Single-threaded, host-side.
    """

    def __init__(self, max_bytes: int = 64 << 20, max_cells: int = 1 << 16):
        self.max_bytes = int(max_bytes)
        self.max_cells = int(max_cells)
        # (epoch, k, h, lo, hi) -> (packed row, n_edges); LRU order
        self._cores: "OrderedDict[tuple, Tuple[np.ndarray, int]]" = \
            OrderedDict()
        # (epoch, k, h) -> {(ts, te) -> None | (lo, hi)}; per-group dicts
        # give dominance scans locality, _cells keeps the global LRU order
        self._groups: Dict[tuple, Dict[tuple, Optional[tuple]]] = {}
        self._cells: "OrderedDict[tuple, None]" = OrderedDict()
        self.bytes = 0
        self.hits = 0            # exact-key cell hits
        self.dominance_hits = 0  # resolved by the monotonicity scan
        self.misses = 0
        self.inserts = 0
        self.invalidated = 0     # entries killed by an ingest batch
        self.rekeyed = 0         # entries carried across an ingest epoch
        self.evicted_cores = 0
        self.evicted_cells = 0

    # ------------------------------------------------------------- internals
    def _cell_del(self, ckey: tuple) -> None:
        gkey, win = ckey[:3], ckey[3:]
        self._cells.pop(ckey, None)
        grp = self._groups.get(gkey)
        if grp is not None:
            grp.pop(win, None)
            if not grp:
                del self._groups[gkey]

    def _cell_put(self, gkey: tuple, win: tuple,
                  outcome: Optional[tuple]) -> None:
        ckey = gkey + win
        if ckey not in self._cells:
            self._groups.setdefault(gkey, {})[win] = outcome
            self._cells[ckey] = None
        self._cells.move_to_end(ckey)
        while len(self._cells) > self.max_cells:
            victim, _ = self._cells.popitem(last=False)
            self._cell_del(victim)
            self.evicted_cells += 1

    def _core_del(self, key: tuple) -> None:
        payload = self._cores.pop(key, None)
        if payload is not None:
            self.bytes -= payload[0].nbytes

    # ----------------------------------------------------------------- reads
    def lookup(self, epoch: int, k: int, h: int, a: int, b: int
               ) -> Optional[CacheHit]:
        """Resolve window ``[a, b]`` at (epoch, k, h), or ``None`` on miss.

        Exact cell hit first; otherwise one dominance scan over the
        group's cells.  A dominance hit is memoized as an exact cell so
        repeats of the same window skip the scan.
        """
        gkey = (int(epoch), int(k), int(h))
        grp = self._groups.get(gkey)
        if grp is None:
            self.misses += 1
            return None
        win = (int(a), int(b))
        out = grp.get(win, _MISS)
        if out is not _MISS:
            hit = self._materialize(gkey, win, out)
            if hit is not None:
                self.hits += 1
                self._cells.move_to_end(gkey + win)
                return hit
            self._cell_del(gkey + win)     # payload was evicted: stale cell
        for (ts, te), o in grp.items():
            if o is None:
                if ts <= win[0] and win[1] <= te:
                    self.dominance_hits += 1
                    self._cell_put(gkey, win, None)
                    return CacheHit(win[0], win[1], 0, None)
            elif ts <= win[0] <= o[0] and o[1] <= win[1] <= te:
                hit = self._materialize(gkey, win, o)
                if hit is not None:
                    self.dominance_hits += 1
                    self._cell_put(gkey, win, o)
                    return hit
        self.misses += 1
        return None

    def _materialize(self, gkey: tuple, win: tuple,
                     outcome: Optional[tuple]) -> Optional[CacheHit]:
        if outcome is None:
            return CacheHit(win[0], win[1], 0, None)
        payload = self._cores.get(gkey + outcome)
        if payload is None:
            return None                    # evicted under memory pressure
        self._cores.move_to_end(gkey + outcome)
        return CacheHit(outcome[0], outcome[1], payload[1], payload[0])

    # ---------------------------------------------------------------- writes
    def insert(self, epoch: int, k: int, h: int, ts: int, te: int,
               lo: int, hi: int, n_edges: int, packed: np.ndarray) -> None:
        """Record a peeled cell ``(ts, te) -> TTI (lo, hi)`` and its core.

        Also records the canonical cell ``(lo, hi) -> (lo, hi)`` — the TTI
        window itself always resolves to the same core (Property 2).
        """
        gkey = (int(epoch), int(k), int(h))
        tti = (int(lo), int(hi))
        ckey = gkey + tti
        if ckey not in self._cores:
            row = np.ascontiguousarray(packed, dtype=np.uint32)
            row.flags.writeable = False    # rows are shared across states
            self._cores[ckey] = (row, int(n_edges))
            self.bytes += row.nbytes
            while self.bytes > self.max_bytes and self._cores:
                victim, (vrow, _) = self._cores.popitem(last=False)
                self.bytes -= vrow.nbytes
                self.evicted_cores += 1
        else:
            self._cores.move_to_end(ckey)
        self.inserts += 1
        self._cell_put(gkey, (int(ts), int(te)), tti)
        if (int(ts), int(te)) != tti:
            self._cell_put(gkey, tti, tti)

    def insert_empty(self, epoch: int, k: int, h: int, ts: int, te: int
                     ) -> None:
        """Record that window ``[ts, te]`` has no (k, h)-core."""
        self.inserts += 1
        self._cell_put((int(epoch), int(k), int(h)), (int(ts), int(te)),
                       None)

    # ------------------------------------------------------------ epoch flow
    def advance_epoch(self, old: int, new: int, batch_lo: int,
                      batch_hi: int) -> Tuple[int, int]:
        """Carry epoch ``old`` entries to ``new`` across an appended batch
        spanning ``[batch_lo, batch_hi]``.

        Cells whose *window* intersects the batch are invalidated (an
        appended edge inside the window can grow the core); core payloads
        whose *TTI* intersects are invalidated (the payload is the core of
        exactly ``[lo, hi]``).  A surviving cell's window avoids the batch
        span, hence so does its TTI — cell and payload survival are
        consistent.  Returns ``(invalidated, rekeyed)`` entry counts.
        """
        inv = moved = 0
        for gkey in [g for g in self._groups if g[0] == old]:
            ngkey = (new,) + gkey[1:]
            for win, out in list(self._groups[gkey].items()):
                self._cell_del(gkey + win)
                if win[0] <= batch_hi and batch_lo <= win[1]:
                    inv += 1
                else:
                    self._cell_put(ngkey, win, out)
                    moved += 1
        for key in [c for c in self._cores if c[0] == old]:
            if key[3] <= batch_hi and batch_lo <= key[4]:
                self._core_del(key)
                inv += 1
            else:
                payload = self._cores.pop(key)
                self._cores[(new,) + key[1:]] = payload
                moved += 1
        self.invalidated += inv
        self.rekeyed += moved
        return inv, moved

    def rebase_epoch(self, old: int, new: int) -> None:
        """Re-key every epoch ``old`` entry to ``new`` (snapshot restore
        renumbering — same graph, new epoch label, nothing invalidated)."""
        if old == new:
            return
        for gkey in [g for g in self._groups if g[0] == old]:
            ngkey = (new,) + gkey[1:]
            for win, out in list(self._groups[gkey].items()):
                self._cell_del(gkey + win)
                self._cell_put(ngkey, win, out)
        for key in [c for c in self._cores if c[0] == old]:
            self._cores[(new,) + key[1:]] = self._cores.pop(key)

    def retire_epochs(self, live: Iterable[int]) -> None:
        """Drop every entry whose epoch is not in ``live`` (mirrors the
        engine's window-TEL retirement when pinned queries drain)."""
        keep = set(int(e) for e in live)
        for gkey in [g for g in self._groups if g[0] not in keep]:
            for win in list(self._groups[gkey]):
                self._cell_del(gkey + win)
                self.evicted_cells += 1
        for key in [c for c in self._cores if c[0] not in keep]:
            self._core_del(key)
            self.evicted_cores += 1

    # --------------------------------------------------------------- observe
    def stats(self) -> Dict[str, int]:
        probes = self.hits + self.dominance_hits + self.misses
        return {
            "hits": self.hits,
            "dominance_hits": self.dominance_hits,
            "misses": self.misses,
            "hit_rate": ((self.hits + self.dominance_hits) / probes
                         if probes else 0.0),
            "inserts": self.inserts,
            "invalidated": self.invalidated,
            "rekeyed": self.rekeyed,
            "evicted_cores": self.evicted_cores,
            "evicted_cells": self.evicted_cells,
            "n_cores": len(self._cores),
            "n_cells": len(self._cells),
            "bytes": self.bytes,
        }

    # ----------------------------------------------------------- persistence
    def state_dict(self) -> Dict[str, np.ndarray]:
        """Flat str->ndarray snapshot (``np.savez``-ready); round-trips
        through :meth:`load_state`.  LRU recency and counters are not
        persisted — a restored cache is warm but freshly ranked."""
        cell_rows = []
        for ckey in self._cells:               # oldest -> newest
            gkey, win = ckey[:3], ckey[3:]
            out = self._groups[gkey][win]
            lo, hi = (0, -1) if out is None else out   # lo > hi == empty
            cell_rows.append(gkey + win + (lo, hi))
        core_keys = list(self._cores.keys())   # oldest -> newest
        packed = [self._cores[k][0] for k in core_keys]
        widths = np.asarray([p.size for p in packed], dtype=np.int64)
        return {
            "cells": np.asarray(cell_rows, dtype=np.int64).reshape(-1, 7),
            "core_keys": np.asarray(core_keys,
                                    dtype=np.int64).reshape(-1, 5),
            "core_edges": np.asarray([self._cores[k][1] for k in core_keys],
                                     dtype=np.int64),
            "core_offsets": np.concatenate(
                [[0], np.cumsum(widths)]).astype(np.int64),
            "core_packed": (np.concatenate(packed).astype(np.uint32)
                            if packed else np.zeros(0, np.uint32)),
            "caps": np.asarray([self.max_bytes, self.max_cells],
                               dtype=np.int64),
        }

    def load_state(self, state) -> None:
        """Install entries from a :meth:`state_dict` snapshot (additive —
        call on a fresh cache for an exact round-trip)."""
        caps = np.asarray(state["caps"], dtype=np.int64)
        self.max_bytes = int(caps[0])
        self.max_cells = int(caps[1])
        keys = np.asarray(state["core_keys"], dtype=np.int64)
        edges = np.asarray(state["core_edges"], dtype=np.int64)
        off = np.asarray(state["core_offsets"], dtype=np.int64)
        flat = np.asarray(state["core_packed"], dtype=np.uint32)
        for i in range(keys.shape[0]):
            row = np.ascontiguousarray(flat[off[i]:off[i + 1]])
            row.flags.writeable = False
            key = tuple(int(x) for x in keys[i])
            if key not in self._cores:
                self._cores[key] = (row, int(edges[i]))
                self.bytes += row.nbytes
        for r in np.asarray(state["cells"], dtype=np.int64):
            e, k, h, ts, te, lo, hi = (int(x) for x in r)
            self._cell_put((e, k, h), (ts, te),
                           None if lo > hi else (lo, hi))

    @classmethod
    def from_state(cls, state) -> "CoreCache":
        cache = cls()
        cache.load_state(state)
        return cache


class CacheView:
    """A :class:`CoreCache` bound to one ``(epoch, k, h)`` — the handle a
    QueryState carries, so scheduler code never sees epoch bookkeeping."""

    __slots__ = ("cache", "epoch", "k", "h")

    def __init__(self, cache: CoreCache, epoch: int, k: int, h: int):
        self.cache = cache
        self.epoch = int(epoch)
        self.k = int(k)
        self.h = int(h)

    def lookup(self, ts: int, te: int) -> Optional[CacheHit]:
        return self.cache.lookup(self.epoch, self.k, self.h, ts, te)

    def insert(self, ts: int, te: int, lo: int, hi: int, n_edges: int,
               packed: np.ndarray) -> None:
        self.cache.insert(self.epoch, self.k, self.h, ts, te, lo, hi,
                          n_edges, packed)

    def insert_empty(self, ts: int, te: int) -> None:
        self.cache.insert_empty(self.epoch, self.k, self.h, ts, te)
