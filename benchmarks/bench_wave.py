"""Beyond-paper engine benches: wave width scaling, Pallas kernel vs XLA
segment-sum degree path, and peel-iteration counts (feeds the roofline's
per-iteration cost model)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.wave import make_segsum_fns, tcd_wave

from benchmarks.common import GRAPH_K, emit, engine, graph, pick_queries, \
    timeit


def run(name: str = "collegemsg"):
    g = graph(name)
    eng = engine(name)
    k = GRAPH_K[name]
    q = pick_queries(name, 1, span_uts=120, seed=3)[0]
    rows = []
    for wave in (1, 4, 16, 64):
        mode = "serial" if wave == 1 else "wave"
        kw = {} if wave == 1 else {"mode": "wave", "wave": wave}
        t = timeit(lambda: eng.query(k, q["ts"], q["te"], **kw), repeat=2)
        res = eng.query(k, q["ts"], q["te"], **kw)
        rows.append({"bench": "wave_width", "graph": name, "wave": wave,
                     "t_s": t, "device_steps": res.stats.device_steps,
                     "cells": res.stats.cells_evaluated,
                     "n_cores": len(res)})

    # kernel-vs-XLA degree path on a standalone wave
    tel = g.device_tel()
    uts = g.unique_ts
    qn = 16
    rng = np.random.default_rng(0)
    idx = rng.integers(0, uts.size - 10, qn)
    ts = jnp.asarray(uts[idx], jnp.int32)
    te = jnp.asarray(uts[np.minimum(idx + 80, uts.size - 1)], jnp.int32)
    alive = jnp.ones((qn, g.num_vertices), bool)
    for use_kernel, label in ((False, "xla_segsum"), (True, "pallas")):
        sp, sv = make_segsum_fns(g, use_kernel=use_kernel)

        def go():
            r = tcd_wave(tel, alive, ts, te, k, 1,
                         num_vertices=g.num_vertices,
                         seg_pair=sp, seg_vert=sv)
            r.alive.block_until_ready()
            return r

        t = timeit(go, repeat=2)
        r = go()
        rows.append({"bench": "degree_path", "graph": name, "path": label,
                     "t_s": t, "iters": int(r.iters),
                     "note": "pallas runs interpret-mode on CPU; the TPU "
                             "comparison is structural (see EXPERIMENTS)"})
    emit("bench_wave", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
