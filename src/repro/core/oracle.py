"""Brute-force oracle: per-subinterval temporal k-core from scratch (numpy).

This is the O(span^2 * |E|) strawman the paper argues against — kept as the
ground truth for every correctness test.  Results are keyed by the *edge set*
(true subgraph identity), which independently validates Property 2
(TTI equality <=> subgraph identity) against the engine's TTI-keyed dedup.
"""

from __future__ import annotations

from typing import Dict, FrozenSet, Tuple

import numpy as np

from repro.core.graph import TemporalGraph


def peel_window(graph: TemporalGraph, ts: int, te: int, k: int,
                h: int = 1) -> np.ndarray:
    """Boolean edge mask of T^k_[ts,te] (empty mask if no core)."""
    win = (graph.t >= ts) & (graph.t <= te)
    alive = np.ones(graph.num_vertices, dtype=bool)
    p = graph.num_pairs
    while True:
        ea = win & alive[graph.src] & alive[graph.dst]
        paircnt = np.bincount(graph.pair_id[ea], minlength=p)
        pairact = paircnt >= h
        deg = (np.bincount(graph.pair_u[pairact], minlength=graph.num_vertices)
               + np.bincount(graph.pair_v[pairact], minlength=graph.num_vertices))
        new = alive & (deg >= k)
        if np.array_equal(new, alive):
            break
        alive = new
    return win & alive[graph.src] & alive[graph.dst]


def brute_force_query(graph: TemporalGraph, k: int, Ts: int, Te: int,
                      h: int = 1) -> Dict[Tuple[int, int], dict]:
    """All distinct temporal k-cores of subintervals of [Ts, Te].

    Returns {tti: {"vertices": frozenset, "n_edges": int, "edges": frozenset}}.
    Raises if two different subgraphs ever map to one TTI (would falsify
    Property 2 — it never happens; the check keeps the oracle honest).
    """
    uts = graph.unique_ts
    uts = uts[(uts >= Ts) & (uts <= Te)]
    out: Dict[Tuple[int, int], dict] = {}
    seen_edges: Dict[FrozenSet[int], Tuple[int, int]] = {}
    for i in range(uts.size):
        for j in range(i, uts.size):
            em = peel_window(graph, int(uts[i]), int(uts[j]), k, h)
            if not em.any():
                continue
            tti = (int(graph.t[em].min()), int(graph.t[em].max()))
            edges = frozenset(np.flatnonzero(em).tolist())
            verts = frozenset(np.unique(
                np.concatenate([graph.src[em], graph.dst[em]])).tolist())
            if tti in out:
                if out[tti]["edges"] != edges:
                    raise AssertionError(
                        f"Property 2 violated at tti={tti}")  # pragma: no cover
            else:
                out[tti] = {"vertices": verts, "n_edges": int(em.sum()),
                            "edges": edges}
            if edges in seen_edges and seen_edges[edges] != tti:
                raise AssertionError("one subgraph, two TTIs")  # pragma: no cover
            seen_edges[edges] = tti
    return out
