"""Model configuration for the assigned-architecture zoo.

One ``ModelConfig`` describes any of the ten architectures (dense GQA, MoE,
RWKV6, Mamba-hybrid, encoder–decoder, VLM/audio backbones).  Layers are
described by per-layer ``LayerSpec``s; the transformer stacks parameters over
the smallest repeating period and scans over it, keeping the lowered HLO
small enough to compile 398B-parameter configs on the CPU dry-run host.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    every: int = 1                 # MoE on every Nth layer (jamba: 2)
    shared_expert: bool = False    # llama4-style always-on shared expert
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclasses.dataclass(frozen=True)
class RWKVCfg:
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"            # attn | mamba | rwkv
    mlp: str = "dense"             # dense | moe
    window: Optional[int] = None   # sliding-window width for local attention
    cross_attn: bool = False       # decoder layers attending to an encoder


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None         # default d_model // n_heads
    act: str = "silu"
    glu: bool = True                        # gated MLP (SwiGLU/GeGLU)
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    post_norms: bool = False                # gemma2 extra post-norms
    pos: str = "rope"                       # rope | mrope | learned | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # qwen2-vl t/h/w split
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    embed_scale: bool = False               # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = True
    local_global_pattern: Optional[int] = None  # gemma2: every Nth is global
    window: Optional[int] = None                # width of local layers
    moe: Optional[MoECfg] = None
    mamba: Optional[MambaCfg] = None
    attn_every: int = 1             # jamba: attention on every Nth layer,
    rwkv: Optional[RWKVCfg] = None  # mamba elsewhere (1 => all-attention)
    mamba_scan: str = "assoc"       # assoc | unroll (perf A/B, §Perf:
    mamba_chunk: int = 256          # unroll loses at XLA op granularity)
    attn_chunk_threshold: int = 8192  # KV len above which attention chunks
    moe_grouped_dispatch: bool = True  # route per batch element (GShard
    #                                    groups): keeps dispatch shard-local
    attn_scores_f32: bool = True    # False: bf16 score materialization
    #                                 (flash-attention traffic proxy, §Perf)
    n_micro_override: Optional[int] = None  # force grad-accum factor
    encoder_layers: int = 0         # >0 => encoder-decoder (whisper)
    input_mode: str = "tokens"      # tokens | embeds (vlm/audio stub frontends)
    max_seq: int = 32_768
    dtype: str = "bfloat16"
    optimizer: str = "adamw"        # adamw | adafactor (biggest configs)
    supports_long_context: bool = False  # may run the long_500k decode cell
    vocab_pad_multiple: int = 256   # pad embed/unembed for TP divisibility
    notes: str = ""

    # ------------------------------------------------------------ derived
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_specs(self) -> List[LayerSpec]:
        """Decoder layer specs (encoders are uniform bidir attention)."""
        specs = []
        for i in range(self.n_layers):
            if self.rwkv is not None:
                mixer = "rwkv"
            elif self.mamba is not None and self.attn_every > 1:
                mixer = "attn" if (i % self.attn_every == self.attn_every - 1) \
                    else "mamba"
            elif self.mamba is not None:
                mixer = "mamba"
            else:
                mixer = "attn"
            window = None
            if mixer == "attn" and self.local_global_pattern:
                if i % self.local_global_pattern != self.local_global_pattern - 1:
                    window = self.window
            mlp = "dense"
            if self.moe is not None and i % self.moe.every == self.moe.every - 1:
                mlp = "moe"
            specs.append(LayerSpec(mixer=mixer, mlp=mlp, window=window,
                                   cross_attn=self.encoder_layers > 0))
        return specs

    def scan_period(self) -> int:
        """Smallest repeating period of the layer pattern (for scan-stacking)."""
        specs = self.layer_specs()
        for p in range(1, len(specs) + 1):
            if len(specs) % p == 0 and all(
                    specs[i] == specs[i % p] for i in range(len(specs))):
                return p
        return len(specs)

    # --------------------------------------------------------- param math
    def _mixer_params(self, spec: LayerSpec) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        if spec.mixer == "attn":
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            bias = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
            x_attn = (q + kv + o) if spec.cross_attn else 0
            return q + kv + o + bias + x_attn
        if spec.mixer == "mamba":
            di = self.mamba.d_inner(d)
            ds = self.mamba.d_state
            dtr = max(1, di // 16)
            return (d * 2 * di                 # in_proj
                    + self.mamba.d_conv * di + di   # conv
                    + di * (dtr + 2 * ds)      # x_dbc
                    + dtr * di + di            # dt_proj + bias
                    + di * ds + di             # A_log + D
                    + di * d)                  # out_proj
        if spec.mixer == "rwkv":
            r = self.rwkv
            lora = 5 * (d * r.mix_lora + r.mix_lora * d) + d * r.decay_lora \
                + r.decay_lora * d
            return 5 * d * d + lora + 9 * d    # r,k,v,g,o + mixes/decay/norm
        return 0

    def _mlp_params(self, spec: LayerSpec) -> Tuple[int, int]:
        """(total, active) parameters of the FFN of one layer."""
        d = self.d_model
        if spec.mixer == "rwkv":  # channel-mix: wu, wd, receptance gate
            n = 2 * d * self.d_ff + d * d + 2 * d
            return n, n
        if spec.mlp == "moe":
            m = self.moe
            nmat = 3 if self.glu else 2
            per = nmat * d * m.d_expert
            total = m.num_experts * per + d * m.num_experts  # + router
            active = m.top_k * per
            if m.shared_expert:
                shared = nmat * d * self.d_ff
                total += shared
                active += shared
            return total, active
        nmat = 3 if self.glu else 2
        per = nmat * d * self.d_ff
        return per, per

    def param_count(self) -> int:
        total = self.padded_vocab * self.d_model * (
            1 if self.tie_embeddings else 2)
        if self.pos == "learned":
            total += self.max_seq * self.d_model
        for spec in self.layer_specs():
            total += self._mixer_params(spec)
            total += self._mlp_params(spec)[0]
            total += 2 * self.d_model  # norms
        # encoder stack (uniform attention + dense mlp)
        enc_spec = LayerSpec(mixer="attn", mlp="dense")
        for _ in range(self.encoder_layers):
            total += self._mixer_params(enc_spec)
            total += self._mlp_params(enc_spec)[0]
            total += 2 * self.d_model
        return total

    def active_param_count(self) -> int:
        active = self.padded_vocab * self.d_model * (
            1 if self.tie_embeddings else 2)
        if self.pos == "learned":
            active += self.max_seq * self.d_model
        for spec in self.layer_specs():
            active += self._mixer_params(spec)
            active += self._mlp_params(spec)[1]
            active += 2 * self.d_model
        enc_spec = LayerSpec(mixer="attn", mlp="dense")
        for _ in range(self.encoder_layers):
            active += self._mixer_params(enc_spec)
            active += self._mlp_params(enc_spec)[1]
            active += 2 * self.d_model
        return active

    def model_flops(self, tokens: int) -> float:
        """MODEL_FLOPS = 6·N_active·D (the roofline 'useful compute' term)."""
        return 6.0 * self.active_param_count() * tokens

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        period = self.scan_period()
        n_layers = max(period, min(2 * period, 4))
        if self.n_layers % period:
            n_layers = period
        d_model = 64
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(self.moe, num_experts=4,
                                      top_k=min(self.moe.top_k, 2),
                                      d_expert=32)
        mamba = MambaCfg(d_state=4, d_conv=4, expand=2) if self.mamba else None
        rwkv = RWKVCfg(head_dim=16, decay_lora=8, mix_lora=8) if self.rwkv else None
        return dataclasses.replace(
            self, n_layers=n_layers, d_model=d_model, n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=16, d_ff=128, vocab=256, max_seq=128,
            window=min(self.window, 16) if self.window else None,
            moe=moe, mamba=mamba, rwkv=rwkv,
            encoder_layers=2 if self.encoder_layers else 0,
            mrope_sections=(2, 3, 3), dtype="float32")
