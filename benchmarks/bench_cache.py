"""TTI core-cache benchmark + regression gate.

Measures what the cache is for: a *repeated-workload* stream (a small set
of hot windows drawn under a Zipf schedule — the serving traffic shape
that motivated the ROADMAP's cache item) served by a warm
``TCQService`` (cache on, steady state) vs a cold one (cache off, every
request recomputes).  Three gates, any failure raises (non-zero harness
exit, same contract as the other gate benches):

* **equivalence** — every warm-served request must be bit-identical
  (``assert_cores_equal``) to the cold recomputation;
* **speedup** — warm steady-state qps must be >= ``_SPEEDUP_FLOOR`` x
  cold qps (5x full-size; relaxed in smoke where graphs are tiny and
  constant overheads dominate);
* **ingest bit-identity** — after >= 3 interleaved ``push_edges``
  epochs (batches landing *inside* the hot windows, so incremental
  invalidation actually fires), every ticket — cache-served or not —
  must match a cold engine recomputed on the ticket's pinned snapshot.

Hit-rate is also gated (>= ``_HIT_RATE_FLOOR`` on the steady-state pass)
so a silently dead cache cannot pass on timing noise.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (SMOKE, assert_cores_equal, emit, graph,
                               pick_queries, timeit)

GRAPH = "email"
_N_DISTINCT = 4 if SMOKE else 8       # hot windows in the working set
_ZIPF_TOTAL = 16 if SMOKE else 64     # requests per measured pass
_SPEEDUP_FLOOR = 2.0 if SMOKE else 5.0
_HIT_RATE_FLOOR = 0.5
_INGEST_ROUNDS = 4                    # 3 appends interleave the serving


def _zipf_schedule(seed: int = 0):
    """The repeated workload: ``_N_DISTINCT`` valid hot windows, drawn
    ``_ZIPF_TOTAL`` times under a Zipf(1.1) popularity law."""
    distinct = pick_queries(GRAPH, _N_DISTINCT, seed=3)
    if not distinct:
        raise RuntimeError("no valid query windows found for cache bench")
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, len(distinct) + 1) ** 1.1
    idx = rng.choice(len(distinct), size=_ZIPF_TOTAL, p=w / w.sum())
    return distinct, [dict(distinct[i]) for i in idx]


def _serve(svc, reqs):
    tickets = [svc.submit({k: r[k] for k in ("k", "ts", "te")})
               for r in reqs]
    svc.run_until_idle()
    return tickets


def run():
    from repro.core import TCQService

    g = graph(GRAPH)
    distinct, reqs = _zipf_schedule()
    rows = []

    cold = TCQService(g, use_kernel=False, cache=False)
    warm = TCQService(g, use_kernel=False, cache=True)
    # one untimed pass each: compiles programs on both and populates the
    # warm cache, so the timed passes compare steady states
    base_cold = _serve(cold, reqs)
    base_warm = _serve(warm, reqs)
    for tc, tw in zip(base_cold, base_warm):
        assert_cores_equal(tw.result, tc.result,
                           f"(cache warm-up, req #{tc.id})")

    t_cold = timeit(lambda: _serve(cold, reqs), repeat=2)
    probes0 = warm.stats["core_cache"]
    tick_warm = []
    t_warm = timeit(lambda: tick_warm.extend(_serve(warm, reqs)), repeat=2)
    for tw, tc in zip(tick_warm, base_cold * 2):
        assert_cores_equal(tw.result, tc.result,
                           f"(cache steady state, req #{tc.id})")
    probes1 = warm.stats["core_cache"]
    d_hits = (probes1["hits"] + probes1["dominance_hits"]
              - probes0["hits"] - probes0["dominance_hits"])
    d_miss = probes1["misses"] - probes0["misses"]
    hit_rate = d_hits / max(1, d_hits + d_miss)
    speedup = t_cold / t_warm if t_warm > 0 else float("inf")

    rows.append({"bench": "cache", "mode": "cold", "t_s": t_cold,
                 "qps": len(reqs) / t_cold})
    rows.append({"bench": "cache", "mode": "warm", "t_s": t_warm,
                 "qps": len(reqs) / t_warm, "hit_rate": hit_rate})
    gate_ok = speedup >= _SPEEDUP_FLOOR and hit_rate >= _HIT_RATE_FLOOR
    rows.append({"bench": "cache_summary",
                 "speedup_warm_vs_cold": speedup, "hit_rate": hit_rate,
                 "distinct_windows": len(distinct),
                 "requests_per_pass": len(reqs),
                 "speedup_floor": _SPEEDUP_FLOOR, "gate_ok": gate_ok})
    if not gate_ok:
        raise RuntimeError(
            f"cache gate: warm vs cold speedup {speedup:.2f}x "
            f"(floor {_SPEEDUP_FLOOR}x) at hit rate {hit_rate:.2%} "
            f"(floor {_HIT_RATE_FLOOR:.0%})")

    rows.append(_run_ingest(distinct))
    emit("bench_cache", rows)
    return rows


def _run_ingest(distinct):
    """Warm-vs-recomputed bit-identity across interleaved ingest epochs.

    Batches land *inside* the hot windows (timestamps drawn from each
    round's target window), so entries genuinely invalidate — then every
    ticket is checked against a cache-less engine on its pinned snapshot.
    """
    import time

    from repro.core import TCQEngine, TCQService

    g = graph(GRAPH)
    rng = np.random.default_rng(11)
    svc = TCQService(g, use_kernel=False, cache=True)   # pins snapshots
    tickets = []
    t0 = time.perf_counter()
    for rnd in range(_INGEST_ROUNDS):
        tickets += _serve(svc, distinct)
        if rnd < _INGEST_ROUNDS - 1:
            # append a batch inside one hot window: its cached cells must
            # invalidate while disjoint windows carry to the new epoch
            tgt = distinct[rnd % len(distinct)]
            n = max(8, svc.graph.num_edges // 200)
            u = rng.integers(0, svc.graph.num_vertices, size=n)
            v = rng.integers(0, svc.graph.num_vertices, size=n)
            t = rng.integers(tgt["ts"], tgt["te"] + 1, size=n)
            svc.push_edges(u, v, t)
    wall = time.perf_counter() - t0
    if svc.epoch < 3:
        raise RuntimeError(f"cache ingest gate: only {svc.epoch} epochs")
    cc = svc.stats["core_cache"]
    if cc["invalidated"] == 0:
        raise RuntimeError("cache ingest gate: appends inside hot windows "
                           "invalidated nothing — invalidation is dead")
    # bit-identity of every (window, epoch) combination vs a cold engine
    # recomputed on the ticket's pinned snapshot
    seen = set()
    for tk in tickets:
        key = (tk.k, tk.h, tk.ts, tk.te, tk.epoch)
        if key in seen:
            continue
        seen.add(key)
        ref = TCQEngine(tk.graph, use_kernel=False).query(
            tk.k, tk.ts, tk.te, h=tk.h, mode="wave")
        assert_cores_equal(tk.result, ref,
                           f"(ingest epoch {tk.epoch}, req #{tk.id})")
    return {"bench": "cache_ingest", "t_s": wall,
            "epochs": int(svc.epoch), "tickets": len(tickets),
            "verified": len(seen), "invalidated": cc["invalidated"],
            "rekeyed": cc["rekeyed"], "hits": cc["hits"],
            "equivalent": True}


if __name__ == "__main__":
    for r in run():
        print(r)
