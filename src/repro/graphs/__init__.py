from repro.graphs.generators import (  # noqa: F401
    erdos_temporal,
    paper_style_example,
    powerlaw_temporal,
    planted_cores,
)
from repro.graphs.io import load_snap_edges, save_edges  # noqa: F401
from repro.graphs.stream import EdgeStream  # noqa: F401
