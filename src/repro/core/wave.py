"""Wave-native batched TCD: Q query cells peeled in lockstep, kernel-ready.

`tcd_batch` (tcd.py) vmaps the scalar path; this module lays the data out
the way the MXU wants it — values [E, Q] / [2P, Q] — so the two segment
reductions become banded one-hot matmuls (the Pallas kernel), and the whole
wave shares one fixpoint loop.  This is also the single-shard block of the
distributed engine (distributed.py wraps it in shard_map with a cross-shard
degree combine).
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.graph import DeviceTEL, TemporalGraph

_I32_MAX = jnp.iinfo(jnp.int32).max


class WaveResult(NamedTuple):
    alive: jnp.ndarray    # [Q, V]
    tti_lo: jnp.ndarray   # [Q]
    tti_hi: jnp.ndarray   # [Q]
    n_edges: jnp.ndarray  # [Q]
    n_verts: jnp.ndarray  # [Q]
    iters: jnp.ndarray    # scalar: fixpoint iterations of the wave


def make_segsum_fns(graph: TemporalGraph, *, use_kernel: bool = False,
                    interpret: Optional[bool] = None):
    """(edges->pairs, halfpairs->vertices) segment-sum closures for a graph.

    use_kernel=True routes through the Pallas banded kernel (interpret mode
    on CPU); False uses jax.ops.segment_sum (XLA scatter path).
    """
    from repro.kernels.segdeg.ops import make_banded_segsum

    tel_hp_src = np.sort(np.concatenate([graph.pair_u, graph.pair_v]))
    seg_pair = make_banded_segsum(graph.pair_id, graph.num_pairs,
                                  use_kernel=use_kernel, interpret=interpret)
    seg_vert = make_banded_segsum(tel_hp_src, graph.num_vertices,
                                  use_kernel=use_kernel, interpret=interpret)
    return seg_pair, seg_vert


def wave_degrees(tel: DeviceTEL, alive: jnp.ndarray, ts, te, h,
                 *, num_vertices: int, seg_pair: Callable, seg_vert: Callable
                 ) -> jnp.ndarray:
    """alive: [Q, V]; ts/te: [Q].  Returns [Q, V] int32 degrees."""
    win = (tel.t[None, :] >= ts[:, None]) & (tel.t[None, :] <= te[:, None])
    ea = win & alive[:, tel.src] & alive[:, tel.dst]          # [Q, E]
    paircnt = seg_pair(ea.T.astype(jnp.float32), tel.pair_id)  # [P, Q]
    pairact = (paircnt >= h).astype(jnp.float32)
    contrib = pairact[tel.hp_pair, :]                          # [2P, Q]
    deg = seg_vert(contrib, tel.hp_src)                        # [V, Q]
    return deg.T.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_vertices", "seg_pair",
                                             "seg_vert", "max_iters"))
def tcd_wave(tel: DeviceTEL, alive: jnp.ndarray, ts, te, k, h,
             *, num_vertices: int, seg_pair, seg_vert,
             max_iters: int = 0) -> WaveResult:
    """Batched TCD to the fixpoint.  alive: [Q, V] warm-start supersets."""
    deg_fn = functools.partial(wave_degrees, tel, num_vertices=num_vertices,
                               seg_pair=seg_pair, seg_vert=seg_vert)

    def cond(state):
        _, changed, it = state
        more = changed
        if max_iters:
            more = more & (it < max_iters)
        return more

    def body(state):
        cur, _, it = state
        deg = deg_fn(cur, ts, te, h)
        new = cur & (deg >= k)
        return new, jnp.any(new != cur), it + 1

    alive, _, iters = lax.while_loop(
        cond, body, (alive, jnp.bool_(True), jnp.int32(0)))
    win = (tel.t[None, :] >= ts[:, None]) & (tel.t[None, :] <= te[:, None])
    ea = win & alive[:, tel.src] & alive[:, tel.dst]
    n_edges = jnp.sum(ea, axis=1, dtype=jnp.int32)
    tti_lo = jnp.min(jnp.where(ea, tel.t[None, :], _I32_MAX), axis=1)
    tti_hi = jnp.max(jnp.where(ea, tel.t[None, :], jnp.int32(-1)), axis=1)
    n_verts = jnp.sum(alive, axis=1, dtype=jnp.int32)
    return WaveResult(alive, tti_lo, tti_hi, n_edges, n_verts, iters)
