"""Device-resident OTCD wave pipeline — the engine behind ``mode="wave"``.

The seed stepwise wave (`otcd.TCQEngine._run_wave_stepwise`, retained as
``mode="wave_stepwise"`` for benchmarking) paid three per-step host costs:
a Python re-stack of W × [V] lane masks into a fresh batch, a blocking
scalar sync before any host bookkeeping could start, and — per discovered
core — an immediate full [V]-bool device→host transfer followed by
``np.flatnonzero``.  This module removes all three:

* **Persistent lane state** — one [W, V] bool buffer lives on device for
  the whole query and is donated through every ``wave_step``; exhausted
  lanes are refilled *in place* with ``lax.dynamic_update_index_in_dim``
  (cold rows from all-ones, warm rows from the best completed row-initial
  core, per Theorem 1), so lane masks never round-trip through the host.

* **Fused step + packed result transfer** — truncate + frontier peel
  (edge activity carried in the fixpoint loop), the TTI reduction,
  per-lane stats, and a ``uint32`` bitmask pack [W, ceil(V/32)] are one
  jitted program.  Each step syncs one packed array plus four small [W]
  vectors — O(W·V/32) words instead of O(W·V) bool bytes — and core
  vertex sets are decoded host-side in a single deferred bulk
  ``np.unpackbits`` at the end of the query.

* **Software-pipelined dispatch** — the schedule runs on two slots that
  ping-pong: while slot B's step executes on device, the host retires
  slot A (pruning Rules 1–3, IntervalSet updates, packed collection),
  reassembles and re-dispatches A, and only then blocks on B's scalars.
  Pruning observed by the in-flight slot is thus one step stale — safe,
  because a stale lane at worst re-induces a core another lane already
  found, and such duplicates are removed by TTI identity (Property 2)
  and counted in ``QueryStats.duplicates``.

* **Kernel degree path** — the Pallas ``banded_segsum`` closures (and
  their k_max band analysis) are built once per ``TCQEngine`` by the
  dispatching wrapper: compiled Pallas on TPU, XLA segment-sum elsewhere.

The pipeline additionally peels against a *windowed* TEL: every schedule
cell lies inside the query's [Ts, Te], so ``TCQEngine._window_tel``
truncates the edge arrays to the window once per query (power-of-two
buckets, sentinel padding) and per-iteration peel work scales with the
window's edge count rather than the whole graph's.
"""

from __future__ import annotations

import functools
from collections import defaultdict, deque
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.graph import DeviceTEL
from repro.core.intervals import IntervalSet
from repro.core.results import CoreResult, QueryStats
from repro.core.wave import peel_to_fixpoint

_I32_MAX = np.iinfo(np.int32).max
_I32_MIN = np.iinfo(np.int32).min


# ------------------------------------------------------------ bitmask pack
def packed_width(num_vertices: int) -> int:
    """uint32 words per packed [V] vertex mask."""
    return max(1, -(-num_vertices // 32))


def _pack_u32(alive: jnp.ndarray, num_vertices: int) -> jnp.ndarray:
    """[..., V] bool -> [..., ceil(V/32)] uint32; vertex v = bit v%32 of
    word v//32 (LSB-first, matching np.unpackbits(bitorder="little"))."""
    w = packed_width(num_vertices)
    pad = w * 32 - num_vertices
    a = jnp.pad(alive, [(0, 0)] * (alive.ndim - 1) + [(0, pad)])
    a = a.reshape(a.shape[:-1] + (w, 32)).astype(jnp.uint32)
    return jnp.sum(a << jnp.arange(32, dtype=jnp.uint32), axis=-1,
                   dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("num_vertices",))
def pack_alive_u32(alive: jnp.ndarray, *, num_vertices: int) -> jnp.ndarray:
    """Standalone jitted pack (used by the distributed engine's packed
    result transfer; ``wave_step`` fuses the same computation inline)."""
    return _pack_u32(alive, num_vertices)


def unpack_alive_u32(packed: np.ndarray, num_vertices: int) -> np.ndarray:
    """Host-side inverse of :func:`pack_alive_u32` — one bulk unpackbits."""
    packed = np.ascontiguousarray(np.asarray(packed).astype("<u4",
                                                            copy=False))
    bits = np.unpackbits(packed.view(np.uint8), axis=-1, bitorder="little")
    return bits[..., :num_vertices].astype(bool)


# ------------------------------------------------------------- fused step
class StepResult(NamedTuple):
    alive: jnp.ndarray    # [W, V] bool — the persistent lane buffer
    packed: jnp.ndarray   # [W, ceil(V/32)] uint32 bitmask of `alive`
    tti_lo: jnp.ndarray   # [W] int32 (I32_MAX when lane core is empty)
    tti_hi: jnp.ndarray   # [W] int32 (I32_MIN when lane core is empty)
    n_edges: jnp.ndarray  # [W] int32
    iters: jnp.ndarray    # scalar int32 — shared fixpoint iterations


@functools.partial(jax.jit,
                   static_argnames=("num_vertices", "seg_pair", "seg_vert"),
                   donate_argnums=(1,))
def wave_step(tel: DeviceTEL, alive: jnp.ndarray, ts, te, k, h,
              *, num_vertices: int, seg_pair, seg_vert) -> StepResult:
    """One fused device step: peel W lanes to the fixpoint + TTI + stats +
    bitmask pack.  ``alive`` is donated — the lane buffer is peeled in
    place and handed back as ``StepResult.alive``."""
    alive, ea, iters = peel_to_fixpoint(
        tel, alive, ts, te, k, h, num_vertices=num_vertices,
        seg_pair=seg_pair, seg_vert=seg_vert)
    n_edges = jnp.sum(ea, axis=1, dtype=jnp.int32)
    tti_lo = jnp.min(jnp.where(ea, tel.t[None, :], _I32_MAX), axis=1)
    tti_hi = jnp.max(jnp.where(ea, tel.t[None, :], _I32_MIN), axis=1)
    return StepResult(alive, _pack_u32(alive, num_vertices),
                      tti_lo, tti_hi, n_edges, iters)


# ---------------------------------------------------------- lane refills
@functools.partial(jax.jit, donate_argnums=(0,))
def _set_lane(buf: jnp.ndarray, li, row: jnp.ndarray) -> jnp.ndarray:
    """In-place (donated) overwrite of lane ``li`` with a device row."""
    return lax.dynamic_update_index_in_dim(buf, row, li, 0)


@functools.partial(jax.jit, static_argnames=("value",), donate_argnums=(0,))
def _fill_lane(buf: jnp.ndarray, li, value: bool) -> jnp.ndarray:
    """In-place (donated) fill of lane ``li`` with a constant mask."""
    row = jnp.full((buf.shape[1],), value, dtype=bool)
    return lax.dynamic_update_index_in_dim(buf, row, li, 0)


# -------------------------------------------------------------- scheduler
class _Row:
    """Cursor of one schedule row: cells (i, j) swept right-to-left."""

    __slots__ = ("i", "j", "first")

    def __init__(self, i: int, n: int):
        self.i, self.j, self.first = i, n - 1, True


class _Slot:
    """One pipeline stage: a device lane buffer + its in-flight step."""

    __slots__ = ("buf", "rows", "dirty", "inflight")

    def __init__(self, wave: int, num_vertices: int):
        self.buf = jnp.zeros((wave, num_vertices), dtype=bool)
        self.rows: List[Optional[_Row]] = [None] * wave
        self.dirty: set = set()   # lanes holding a stale (dead) mask
        self.inflight: Optional[StepResult] = None


class WavePipeline:
    """Two-slot software-pipelined OTCD scheduler over :func:`wave_step`.

    Shared bookkeeping (pruned IntervalSets per row, the empty-cell
    staircase, warm-start rows) mirrors the serial engine; result
    collection stores packed bitmask rows and defers vertex decoding to
    one bulk unpack at the end of the query.
    """

    def __init__(self, tel: DeviceTEL, num_vertices: int,
                 seg_pair, seg_vert, wave: int):
        self.tel = tel
        self.num_vertices = num_vertices
        self.seg_pair = seg_pair
        self.seg_vert = seg_vert
        self.wave = wave

    def run(self, uts: np.ndarray, k: int, h: int, prune: bool,
            stats: QueryStats) -> Dict[Tuple[int, int], CoreResult]:
        n = uts.size
        W = self.wave
        idx_of = {int(t): i for i, t in enumerate(uts)}
        pruned: Dict[int, IntervalSet] = defaultdict(IntervalSet)
        empty_marks: List[Tuple[int, int]] = []
        best_init: Optional[Tuple[int, int, jnp.ndarray]] = None
        pending = deque(range(n))
        # tti key -> (packed uint32 row, n_edges) — decoded in bulk at the end
        collected: Dict[Tuple[int, int], Tuple[np.ndarray, int]] = {}
        kj, hj = jnp.int32(k), jnp.int32(h)

        def empty_bound(r: int) -> int:
            return max((je for ie, je in empty_marks if ie <= r), default=-1)

        def advance(row: _Row) -> bool:
            """Move cursor past pruned/empty cells; False once exhausted."""
            j = pruned[row.i].highest_uncovered_leq(row.j)
            if j is None or j < row.i or j <= empty_bound(row.i):
                return False
            row.j = j
            return True

        def assemble(slot: _Slot) -> None:
            """Claim pending rows into free lanes and refill their masks."""
            for li in range(W):
                if slot.rows[li] is not None:
                    continue
                row = None
                while pending:
                    cand = _Row(pending.popleft(), n)
                    if advance(cand):
                        row = cand
                        break
                if row is None:
                    break
                slot.rows[li] = row
                if (best_init is not None and best_init[0] <= row.i
                        and best_init[1] >= row.j):
                    slot.buf = _set_lane(slot.buf, li, best_init[2])
                else:
                    slot.buf = _fill_lane(slot.buf, li, True)
                slot.dirty.discard(li)
                stats.lane_refills += 1
            # lanes that died and were not re-claimed: zero once so the
            # shared fixpoint loop never spends iterations peeling them
            for li in sorted(slot.dirty):
                slot.buf = _fill_lane(slot.buf, li, False)
            slot.dirty.clear()

        def dispatch(slot: _Slot) -> None:
            occupied = [li for li in range(W) if slot.rows[li] is not None]
            if not occupied:
                slot.inflight = None
                return
            ts_arr = np.zeros(W, np.int32)
            te_arr = np.full(W, -1, np.int32)
            for li in occupied:
                ts_arr[li] = int(uts[slot.rows[li].i])
                te_arr[li] = int(uts[slot.rows[li].j])
            slot.inflight = wave_step(
                self.tel, slot.buf, jnp.asarray(ts_arr), jnp.asarray(te_arr),
                kj, hj, num_vertices=self.num_vertices,
                seg_pair=self.seg_pair, seg_vert=self.seg_vert)
            slot.buf = slot.inflight.alive   # donated through; new handle
            stats.device_steps += 1
            stats.cells_evaluated += len(occupied)

        def retire(slot: _Slot) -> None:
            nonlocal best_init
            res = slot.inflight
            slot.inflight = None
            packed, lo, hi, ne, it = jax.device_get(
                (res.packed, res.tti_lo, res.tti_hi, res.n_edges, res.iters))
            stats.host_syncs += 1
            stats.bytes_synced += (packed.nbytes + lo.nbytes + hi.nbytes
                                   + ne.nbytes + it.nbytes)
            stats.peel_iters += int(it)
            for li in range(W):
                row = slot.rows[li]
                if row is None:
                    continue
                i, j = row.i, row.j
                if int(ne[li]) == 0:
                    empty_marks.append((i, j))   # staircase: row exhausted
                    slot.rows[li] = None
                    slot.dirty.add(li)
                    continue
                a_idx = idx_of[int(lo[li])]
                b_idx = idx_of[int(hi[li])]
                key = (int(lo[li]), int(hi[li]))
                if key in collected:
                    stats.duplicates += 1
                else:
                    collected[key] = (packed[li].copy(), int(ne[li]))
                if row.first and (best_init is None or j >= best_init[1]):
                    best_init = (i, j, res.alive[li])
                row.first = False
                if prune:
                    if b_idx < j:                        # Rule 1: PoR
                        stats.por_triggers += 1
                        stats.pruned_por += pruned[i].add(b_idx, j - 1)
                    if a_idx > i:                        # Rule 2: PoU
                        stats.pou_triggers += 1
                        for r2 in range(i + 1, a_idx + 1):
                            stats.pruned_pou += pruned[r2].add(r2, j)
                    if a_idx > i and b_idx < j:          # Rule 3: PoL
                        stats.pol_triggers += 1
                        for r2 in range(a_idx + 1, b_idx + 1):
                            stats.pruned_pol += pruned[r2].add(b_idx + 1, j)
                    row.j = (b_idx - 1) if b_idx < j else j - 1
                else:
                    row.j = j - 1
                if not advance(row):
                    slot.rows[li] = None
                    slot.dirty.add(li)

        # prime both slots, then ping-pong: retire+reassemble+redispatch one
        # slot while the other's step is still executing on device — the
        # host's pruning bookkeeping overlaps device compute, and a step is
        # always dispatched before we block on the previous step's scalars
        slots = [_Slot(W, self.num_vertices), _Slot(W, self.num_vertices)]
        for slot in slots:
            assemble(slot)
            dispatch(slot)
        cur = 0
        while slots[0].inflight is not None or slots[1].inflight is not None:
            slot = slots[cur]
            if slot.inflight is not None:
                retire(slot)
                assemble(slot)
                dispatch(slot)
            cur ^= 1

        # deferred bulk decode: one unpackbits over every collected core
        results: Dict[Tuple[int, int], CoreResult] = {}
        if collected:
            keys = list(collected.keys())
            bits = unpack_alive_u32(
                np.stack([collected[key][0] for key in keys]),
                self.num_vertices)
            for key, row_bits in zip(keys, bits):
                results[key] = CoreResult(
                    k=k, tti=key, vertices=np.flatnonzero(row_bits),
                    n_edges=collected[key][1])
        return results
