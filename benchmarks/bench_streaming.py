"""Streaming service runtime: clustered pools vs one union-window pool,
and sustained throughput under concurrent edge ingestion.

Two measurements, both doubling as regression gates (any divergence
raises, so ``python -m benchmarks.run`` exits non-zero):

1. **Window-clustered batching** — a request set whose windows form
   disjoint far-apart groups is the worst case for
   ``TCQEngine.query_batch``'s single union-window TEL: every fused
   peel iteration pays for the union's edges while each lane only needs
   its own cluster's.  ``TCQService`` groups the same requests by window
   overlap and runs one tight pool per cluster.  Results must be
   identical request-for-request; the summary row records the speedup.

2. **Sustained qps with concurrent ingestion** — requests are injected
   through the service's poll hook (arrivals land mid-flight) while
   edge batches are pushed between waves, each push a new TEL epoch.
   Every ticket is checked bit-identical to an isolated query on its
   *pinned snapshot* — the snapshot-consistency gate: no query may
   observe edges pushed after its admission.

Rows feed benchmarks/results/bench_streaming.json and the
BENCH_wave.json ``streaming`` trajectory.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import (GRAPH_K, assert_cores_equal, emit, engine,
                               graph, timeit)

N_GROUPS = 3        # disjoint window clusters spread over the timeline
PER_GROUP = 3       # nested requests within each cluster
SPAN_UTS = 36       # unique timestamps per cluster's widest window
NEST_UTS = 4        # shrink per zoom-in request inside a cluster
GROUP_FRACS = (0.06, 0.48, 0.88)    # cluster starts (fraction of timeline)


def disjoint_requests(name: str):
    """N_GROUPS x PER_GROUP mixed-k requests: each group is a nested
    zoom-in staircase (a natural drill-down pattern, and later members
    fit a live pool built from the widest), groups sit far apart on the
    timeline — the anti-union workload."""
    uts = graph(name).unique_ts
    k0 = GRAPH_K[name]
    n = int(uts.size)
    reqs = []
    for gi, frac in enumerate(GROUP_FRACS[:N_GROUPS]):
        s0 = min(int(frac * n), max(0, n - SPAN_UTS - 2))
        for i in range(PER_GROUP):
            i0 = min(s0 + i * NEST_UTS, n - 2)
            j0 = max(i0 + 1, min(s0 + SPAN_UTS - i * NEST_UTS, n - 1))
            reqs.append({"k": k0 + (i % 2), "ts": int(uts[i0]),
                         "te": int(uts[j0])})
    return reqs


def _serve_clustered(eng, reqs):
    from repro.core import TCQService

    svc = TCQService(graph=None, engine=eng)
    tickets = [svc.submit(r) for r in reqs]
    svc.run_until_idle()
    return svc, tickets


def run_clustered_vs_union(name: str, repeat: int):
    eng = engine(name)
    reqs = disjoint_requests(name)

    union = lambda: eng.query_batch(reqs)  # noqa: E731
    clustered = lambda: _serve_clustered(eng, reqs)  # noqa: E731

    union_res = union()                    # warm compile caches + gate refs
    svc, tickets = clustered()
    # snapshot-consistency gate: clustered pools must return exactly the
    # union pool's per-request results (both bit-identical to isolation)
    for r, tk, want in zip(reqs, tickets, union_res):
        assert_cores_equal(tk.result, want,
                           ctx=f"clustered vs union on {name} {r}")

    t_union = timeit(union, repeat=repeat)
    t_clustered = timeit(clustered, repeat=repeat)
    union_stats = next(r.stats for r in union_res if r.stats.device_steps)
    pool_edges = [p["window_edges"] for p in svc.pool_log]
    rows = [
        {"bench": "streaming", "graph": name, "mode": "union_pool",
         "n_queries": len(reqs), "t_s": t_union,
         "qps": len(reqs) / t_union,
         "window_edges": union_stats.window_edges,
         "device_steps": union_stats.device_steps,
         "occupancy": union_stats.occupancy},
        {"bench": "streaming", "graph": name, "mode": "clustered",
         "n_queries": len(reqs), "t_s": t_clustered,
         "qps": len(reqs) / t_clustered,
         "pools": len(svc.pool_log),
         "window_edges_per_pool": pool_edges,
         "occupancy": float(np.mean(
             [p["occupancy"] for p in svc.pool_log]))},
        {"bench": "streaming_summary", "graph": name,
         "n_queries": len(reqs), "n_clusters": len(svc.pool_log),
         "speedup_clustered_vs_union": t_union / t_clustered,
         "union_window_edges": union_stats.window_edges,
         "max_cluster_window_edges": max(pool_edges),
         "equivalent": True},     # the gate above raised otherwise
    ]
    return rows


def run_ingest(name: str, n_requests: int = 12, ingest_every: int = 4,
               burst: int = 2):
    """Sustained service: bursty arrivals injected mid-flight via poll,
    edge batches pushed between waves (new epoch each), snapshot gate
    on.  Within a burst the widest window arrives first, so later
    members of the same cluster can join its live pool mid-flight."""
    from repro.core import TCQEngine, TCQService
    from repro.graphs import EdgeStream, powerlaw_temporal

    g0 = graph(name)
    lo, hi = g0.span
    base_reqs = disjoint_requests(name)     # widest window leads each group
    queue = [dict(base_reqs[i % len(base_reqs)]) for i in range(n_requests)]
    future = powerlaw_temporal(g0.num_vertices, max(g0.num_edges // 10, 64),
                               (hi - lo) // 4 + 1, seed=91)
    batches = [(u, v, t + hi) for u, v, t in
               EdgeStream.replay(future, max(2, n_requests // ingest_every))]

    svc = TCQService(g0)        # fresh engine: ingestion must not poison
    state = {"submitted": 0}    # the shared bench engine cache

    def poll(s):
        if state["submitted"] < len(queue):
            for _ in range(burst):
                if state["submitted"] >= len(queue):
                    break
                s.submit(queue[state["submitted"]])
                state["submitted"] += 1
            if state["submitted"] % ingest_every == 0 and batches:
                u, v, t = batches.pop(0)
                s.push_edges(u, v, t)

    # warm the compile caches on one throwaway query
    svc.submit(queue[0]); svc.run_until_idle()
    served0 = list(svc.completed); svc.completed.clear(); svc.pool_log.clear()
    del served0

    import time
    t0 = time.perf_counter()
    served = svc.run_until_idle(poll)
    wall = time.perf_counter() - t0
    assert len(served) == n_requests, (len(served), n_requests)

    # snapshot-consistency gate: every ticket == isolated query on its
    # pinned epoch snapshot (no query observes post-admission edges)
    engines = {}
    for tk in served:
        if tk.epoch not in engines:
            engines[tk.epoch] = TCQEngine(tk.graph)
        want = engines[tk.epoch].query(tk.k, tk.ts, tk.te, h=tk.h)
        assert_cores_equal(tk.result, want,
                           ctx=f"snapshot consistency {name} ticket {tk.id} "
                               f"epoch {tk.epoch}")

    lat = np.array([tk.latency_s for tk in served])
    return [{
        "bench": "streaming_ingest", "graph": name,
        "n_queries": n_requests, "t_s": wall, "qps": n_requests / wall,
        "epochs_ingested": svc.epoch,
        "pools": len(svc.pool_log),
        "admitted_midflight": sum(p["admitted_midflight"]
                                  for p in svc.pool_log),
        "p50_ms": 1e3 * float(np.quantile(lat, .5)),
        "p95_ms": 1e3 * float(np.quantile(lat, .95)),
        "snapshot_consistent": True,    # the gate above raised otherwise
    }]


def run(name: str = "collegemsg", repeat: int = 2):
    rows = run_clustered_vs_union(name, repeat)
    rows += run_ingest(name)
    emit("bench_streaming", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
