"""Sharded streaming pipeline benchmark + equivalence gate.

Drains one overlapping-window request batch through the single-device
``TCQService`` and through mesh-backed services on several shapes of an
8-virtual-device mesh (``--xla_force_host_platform_device_count=8`` in a
subprocess: jax locks the device count at first init).  Reports per-shape
aggregate qps and scaling efficiency, asserts every sharded run is
bit-identical to the single-device drain, and enforces the aggregate-qps
floor: the best mesh shape must beat the single-device pipeline by at
least ``REPRO_DIST_FLOOR`` (default 1.5x).

On one physical CPU core the win is host-overhead amortization — a
lane-sharded pool packs ``lane_shards`` times the lanes into each
dispatched step, so per-step dispatch/fetch/bookkeeping is paid once for
L shards' worth of peeling (~6x fewer device steps here) — which is
exactly the term that survives on real multi-chip meshes after per-chip
compute stops shrinking.  The workload is sized so per-step host overhead
is a visible fraction of the drain (small dense graph, many overlapping
windows); timing interleaves single/mesh rounds and takes best-of-N per
engine so background load on the host hits both pipelines alike.

``REPRO_BENCH_SMOKE=1`` times only the widest mesh shape (CI mode); the
floor is enforced in both modes.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List

from benchmarks.common import SMOKE

FLOOR = float(os.environ.get("REPRO_DIST_FLOOR", "1.5"))

# mesh shapes (lane_shards, model_shards) over the 8 fake devices
SHAPES = [(8, 1), (4, 2), (2, 4)]

# Tuned drain: V/E/span small enough that one peel step is host-overhead
# bound, 64 half-span windows so the lane pools stay saturated.  depth=1
# for both engines — with host and virtual devices sharing one core there
# is no compute to overlap, and a deeper ring only adds in-flight staleness.
CFG = {"V": 64, "E": 192, "span": 128, "requests": 64, "k": 2,
       "depth": 1, "rounds": 3}

_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys; sys.path.insert(0, "src")
import json, time
import numpy as np, jax
from repro.core import TCQService
from repro.graphs import powerlaw_temporal

cfg = json.loads(sys.argv[1])
g = powerlaw_temporal(cfg["V"], cfg["E"], cfg["span"], seed=9)
lo, hi = g.span
rng = np.random.default_rng(1)
reqs = []
for _ in range(cfg["requests"]):
    a = int(rng.integers(lo, lo + max(1, (hi - lo) // 3)))
    b = a + (hi - lo) // 2 + int(rng.integers(0, max(1, (hi - lo) // 6)))
    reqs.append(dict(k=cfg["k"], ts=a, te=min(b, hi)))


def mk(mesh):
    kw = {} if mesh is None else {"mesh": mesh}
    return TCQService(g, cache=False, retain_snapshots=False,
                      depth=cfg["depth"], **kw)


def drain_round(svc):
    for r in reqs:
        svc.submit(r)
    t0 = time.perf_counter()
    out = svc.run_until_idle()
    dt = time.perf_counter() - t0
    svc.completed.clear()
    return dt, out


def digest(tickets):
    out = []
    for t in sorted(tickets, key=lambda t: t.id):
        out.append(sorted((k, tuple(c.vertices.tolist()), c.n_edges)
                          for k, c in t.result.by_tti().items()))
    return out


entries = [("single", None)]
for L, M in cfg["shapes"]:
    entries.append((f"{L}x{M}", jax.make_mesh((L, M), ("data", "model"))))

svcs, digests = {}, {}
for name, mesh in entries:                 # warm round: compiles + digest
    svcs[name] = mk(mesh)
    _, out = drain_round(svcs[name])
    digests[name] = digest(out)
want = digests["single"]

best = {name: float("inf") for name, _ in entries}
for _ in range(cfg["rounds"]):             # interleave: noise hits all alike
    for name, _ in entries:
        dt, _ = drain_round(svcs[name])
        best[name] = min(best[name], dt)

base_wall = best["single"]
rows = [{"bench": "distributed", "mesh": "single", "devices": 1,
         "combine": "-", "t_s": base_wall,
         "qps": len(reqs) / base_wall, "speedup": 1.0, "efficiency": 1.0,
         "equivalent": True, "collective_bytes": 0,
         "mean_shard_occupancy": 0.0}]
for (L, M), (name, _) in zip(cfg["shapes"], entries[1:]):
    svc, wall = svcs[name], best[name]
    occ = [p["shard_occupancy"] for p in svc.pool_log
           if p.get("shard_occupancy")]
    rows.append({"bench": "distributed", "mesh": name, "devices": L * M,
                 "combine": svc.stats["distributed"]["combine"],
                 "t_s": wall, "qps": len(reqs) / wall,
                 "speedup": base_wall / wall,
                 "efficiency": base_wall / wall / (L * M),
                 "equivalent": digests[name] == want,
                 "collective_bytes":
                     svc.stats["distributed"]["collective_bytes"],
                 "mean_shard_occupancy":
                     (float(np.mean([np.mean(o) for o in occ]))
                      if occ else 0.0)})
print("ROWS::" + json.dumps(rows))
"""


def run() -> List[dict]:
    cfg = dict(CFG)
    cfg["shapes"] = SHAPES[:1] if SMOKE else SHAPES
    out = subprocess.run(
        [sys.executable, "-c", _WORKER, json.dumps(cfg)],
        capture_output=True, text=True, timeout=1800,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    if out.returncode != 0:
        raise RuntimeError("bench_distributed worker failed:\n"
                           + out.stderr[-3000:])
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("ROWS::")][-1]
    rows = json.loads(line[len("ROWS::"):])

    bad = [r["mesh"] for r in rows if not r["equivalent"]]
    if bad:
        raise RuntimeError(
            f"sharded pipeline diverged from single-device on {bad}")
    best = max((r for r in rows if r["mesh"] != "single"),
               key=lambda r: r["speedup"])
    gate_ok = best["speedup"] >= FLOOR
    rows.append({"bench": "distributed_speedup", "best_mesh": best["mesh"],
                 "speedup": best["speedup"], "floor": FLOOR,
                 "gate_ok": gate_ok})
    if not gate_ok:
        raise RuntimeError(
            f"aggregate-qps floor violated: best mesh {best['mesh']} is "
            f"{best['speedup']:.2f}x single-device (floor {FLOOR}x)")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
