"""Pallas TPU kernel: diagonal SSM scan with VMEM-resident state.

EXPERIMENTS §Perf (jamba hillclimb, iteration 1) showed that neither
`associative_scan` (2·log2(c) full-array HBM passes) nor an unrolled chunk
(per-step carry round-trips at XLA op granularity) reaches the intrinsic
traffic of the Mamba recurrence.  This kernel does: the running state lives
in a VMEM scratch across the sequential grid dimension, so HBM traffic is
exactly read(log_a) + read(bx) + write(states) — 3 passes instead of ~24.

Grid: (B, F_tiles, S_chunks) with S innermost/sequential; the scratch
carries (1, F_TILE) state between consecutive chunks of the same (b, f)
lane.  Validated in interpret mode against ref.ssm_scan_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _kernel(la_ref, bx_ref, s0_ref, out_ref, state, *, s_chunk: int):
    sc = pl.program_id(2)

    @pl.when(sc == 0)
    def _init():
        state[0, :] = s0_ref[0, :]

    def step(i, _):
        new = jnp.exp(la_ref[0, i, :]) * state[0, :] + bx_ref[0, i, :]
        state[0, :] = new
        out_ref[0, i, :] = new
        return 0

    jax.lax.fori_loop(0, s_chunk, step, 0)


@functools.partial(jax.jit, static_argnames=("s_chunk", "f_tile",
                                             "interpret"))
def ssm_scan_pallas(log_a: jnp.ndarray, bx: jnp.ndarray, s0: jnp.ndarray,
                    *, s_chunk: int = 128, f_tile: int = 512,
                    interpret: bool = True) -> jnp.ndarray:
    """log_a/bx: [B, S, F]; s0: [B, F] -> all states [B, S, F] (f32)."""
    b, s, f = log_a.shape
    s_pad = -(-s // s_chunk) * s_chunk
    f_pad = -(-f // f_tile) * f_tile
    la = jnp.pad(log_a.astype(jnp.float32),
                 ((0, 0), (0, s_pad - s), (0, f_pad - f)))
    bxp = jnp.pad(bx.astype(jnp.float32),
                  ((0, 0), (0, s_pad - s), (0, f_pad - f)))
    s0p = jnp.pad(s0.astype(jnp.float32), ((0, 0), (0, f_pad - f)))
    grid = (b, f_pad // f_tile, s_pad // s_chunk)

    def in_idx(bi, fi, si):
        return (bi, si, fi)

    def s0_idx(bi, fi, si):
        return (bi, fi)

    out = pl.pallas_call(
        functools.partial(_kernel, s_chunk=s_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s_chunk, f_tile), in_idx),
            pl.BlockSpec((1, s_chunk, f_tile), in_idx),
            pl.BlockSpec((1, f_tile), s0_idx),
        ],
        out_specs=pl.BlockSpec((1, s_chunk, f_tile), in_idx),
        out_shape=jax.ShapeDtypeStruct((b, s_pad, f_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, f_tile), jnp.float32)]
        if pltpu else None,
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(la, bxp, s0p)
    return out[:, :s, :f]
