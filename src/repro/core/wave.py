"""Wave-native batched TCD: Q query cells peeled in lockstep, kernel-ready.

`tcd_batch` (tcd.py) vmaps the scalar path; this module lays the data out
the way the MXU wants it — values [E, Q] / [2P, Q] — so the two segment
reductions become banded one-hot matmuls (the Pallas segdeg kernel), and
the whole wave shares one fixpoint loop.  The edge-activity / degree
split lets callers carry edge activity through the fixpoint loop and
skip the post-loop edge pass.  This is also the single-shard block of
the distributed engine (distributed.py wraps it in shard_map with a
cross-shard degree combine).

The device step itself — :func:`wave_step` (peel + TTI + stats + uint32
bitmask pack in one program) — lives here too, with two lowerings behind
one dispatcher, :func:`make_wave_step_fn`:

  * **fused Pallas** (``kernels/wave_peel``): the entire fixpoint loop
    runs on-chip per W-tile — no [W, E] HBM round-trips between
    iterations (compiled on TPU, interpret mode for CPU gates);
  * **XLA composite** (this module's ``peel_to_fixpoint`` chain): the
    portable fallback, also used when a TEL's VMEM working set exceeds
    the kernel budget.

Both lowerings are bit-identical (seeded fuzz gate in
tests/test_kernels.py); ``engine.WavePipeline``, :func:`tcd_wave` and
the distributed engine's single-shard block all route through the
dispatcher, so one kernel serves the single-query, batched and sharded
engines.
"""

from __future__ import annotations

import functools
import weakref
from collections import OrderedDict
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.graph import DeviceTEL, TemporalGraph

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min


class WaveResult(NamedTuple):
    alive: jnp.ndarray    # [Q, V]
    tti_lo: jnp.ndarray   # [Q]
    tti_hi: jnp.ndarray   # [Q]
    n_edges: jnp.ndarray  # [Q]
    n_verts: jnp.ndarray  # [Q]
    iters: jnp.ndarray    # scalar: fixpoint iterations of the wave


# ------------------------------------------------------- segsum closures
# (id(graph), epoch, use_kernel, interpret) -> (weakref(graph), closures).
# The band analysis (np.sort over 2P half-pairs + the kernel's k_max pass)
# used to rerun on every engine/bench construction for the same snapshot;
# epochs are immutable, so it is cacheable.  The weakref guards against
# id() reuse after a graph is collected.
_SEGSUM_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_SEGSUM_CACHE_MAX = 16


def make_segsum_fns(graph: TemporalGraph, *, use_kernel: Optional[bool] = None,
                    interpret: Optional[bool] = None):
    """(edges->pairs, halfpairs->vertices) segment-sum closures for a graph.

    use_kernel=True routes through the Pallas banded kernel (interpret mode
    on CPU); False uses jax.ops.segment_sum (XLA scatter path); None (the
    default) auto-dispatches — compiled Pallas on TPU, XLA elsewhere.  The
    band analysis (k_max) runs once per ``(graph, epoch)`` and is cached
    (graphs are immutable snapshots; appends bump ``epoch``).
    """
    from repro.kernels.segdeg.ops import make_banded_segsum, on_tpu

    if use_kernel is None:
        use_kernel = on_tpu()
    key = (id(graph), graph.epoch, bool(use_kernel), interpret)
    hit = _SEGSUM_CACHE.get(key)
    if hit is not None and hit[0]() is graph:
        _SEGSUM_CACHE.move_to_end(key)
        return hit[1]
    tel_hp_src = np.sort(np.concatenate([graph.pair_u, graph.pair_v]))
    seg_pair = make_banded_segsum(graph.pair_id, graph.num_pairs,
                                  use_kernel=use_kernel, interpret=interpret)
    seg_vert = make_banded_segsum(tel_hp_src, graph.num_vertices,
                                  use_kernel=use_kernel, interpret=interpret)
    fns = (seg_pair, seg_vert)
    _SEGSUM_CACHE[key] = (weakref.ref(graph), fns)
    while len(_SEGSUM_CACHE) > _SEGSUM_CACHE_MAX:
        _SEGSUM_CACHE.popitem(last=False)
    return fns


def wave_edge_activity(tel: DeviceTEL, alive: jnp.ndarray, ts, te
                       ) -> jnp.ndarray:
    """alive: [Q, V]; ts/te: [Q].  Returns [Q, E] bool edge activity."""
    win = (tel.t[None, :] >= ts[:, None]) & (tel.t[None, :] <= te[:, None])
    return win & alive[:, tel.src] & alive[:, tel.dst]


def wave_degrees_from_ea(tel: DeviceTEL, ea: jnp.ndarray, h,
                         *, num_vertices: int, seg_pair: Callable,
                         seg_vert: Callable) -> jnp.ndarray:
    """ea: [Q, E] edge activity; h: scalar or per-lane [Q].
    Returns [Q, V] int32 degrees."""
    paircnt = seg_pair(ea.T.astype(jnp.float32), tel.pair_id)  # [P, Q]
    pairact = (paircnt >= h).astype(jnp.float32)   # h broadcasts over lanes
    contrib = pairact[tel.hp_pair, :]                          # [2P, Q]
    deg = seg_vert(contrib, tel.hp_src)                        # [V, Q]
    return deg.T.astype(jnp.int32)


def wave_degrees(tel: DeviceTEL, alive: jnp.ndarray, ts, te, h,
                 *, num_vertices: int, seg_pair: Callable, seg_vert: Callable
                 ) -> jnp.ndarray:
    """alive: [Q, V]; ts/te: [Q].  Returns [Q, V] int32 degrees."""
    ea = wave_edge_activity(tel, alive, ts, te)
    return wave_degrees_from_ea(tel, ea, h, num_vertices=num_vertices,
                                seg_pair=seg_pair, seg_vert=seg_vert)


def peel_to_fixpoint(tel: DeviceTEL, alive: jnp.ndarray, ts, te, k, h,
                     *, num_vertices: int, seg_pair, seg_vert,
                     max_iters: int = 0):
    """Shared batched peel loop -> (alive, ea, iters); trace-time building
    block for `tcd_wave` and the composite ``wave_step`` lowering.

    k and h may be scalars (one threshold for the whole wave) or per-lane
    [Q] vectors — the multi-tenant scheduler packs cells from queries with
    different (k, h) into one wave, so the survivor test broadcasts the
    thresholds per lane.

    ea rides in the carry (as in tcd.tcd): the final iteration observed
    new == cur, so the carried ea is exactly the fixpoint's edge activity
    and callers skip the post-loop edge pass.
    """
    q = alive.shape[0]
    k_lane = jnp.broadcast_to(jnp.asarray(k, jnp.int32), (q,))
    h_lane = jnp.broadcast_to(jnp.asarray(h, jnp.int32), (q,))
    ts = jnp.broadcast_to(jnp.asarray(ts, jnp.int32), (q,))
    te = jnp.broadcast_to(jnp.asarray(te, jnp.int32), (q,))
    # the [Q, E] window mask depends only on (ts, te), never on alive —
    # computed once, reused by every fixpoint iteration (it used to be
    # rebuilt inside the loop body on this path)
    win = (tel.t[None, :] >= ts[:, None]) & (tel.t[None, :] <= te[:, None])

    def edge_activity(cur):
        return win & cur[:, tel.src] & cur[:, tel.dst]

    def cond(state):
        _, _, changed, it = state
        more = changed
        if max_iters:
            more = more & (it < max_iters)
        return more

    def body(state):
        cur, _, _, it = state
        ea = edge_activity(cur)
        deg = wave_degrees_from_ea(tel, ea, h_lane,
                                   num_vertices=num_vertices,
                                   seg_pair=seg_pair, seg_vert=seg_vert)
        new = cur & (deg >= k_lane[:, None])
        return new, ea, jnp.any(new != cur), it + 1

    ea0 = jnp.zeros((alive.shape[0], tel.t.shape[0]), dtype=bool)
    alive, ea, _, iters = lax.while_loop(
        cond, body, (alive, ea0, jnp.bool_(True), jnp.int32(0)))
    if max_iters:  # truncated peel may exit pre-fixpoint: ea would be stale
        ea = edge_activity(alive)
    return alive, ea, iters


# ------------------------------------------------------------ bitmask pack
def packed_width(num_vertices: int) -> int:
    """uint32 words per packed [V] vertex mask."""
    return max(1, -(-num_vertices // 32))


def _pack_u32(alive: jnp.ndarray, num_vertices: int) -> jnp.ndarray:
    """[..., V] bool -> [..., ceil(V/32)] uint32; vertex v = bit v%32 of
    word v//32 (LSB-first, matching np.unpackbits(bitorder="little"))."""
    w = packed_width(num_vertices)
    pad = w * 32 - num_vertices
    a = jnp.pad(alive, [(0, 0)] * (alive.ndim - 1) + [(0, pad)])
    a = a.reshape(a.shape[:-1] + (w, 32)).astype(jnp.uint32)
    return jnp.sum(a << jnp.arange(32, dtype=jnp.uint32), axis=-1,
                   dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("num_vertices",))
def pack_alive_u32(alive: jnp.ndarray, *, num_vertices: int) -> jnp.ndarray:
    """Standalone jitted pack (used by the distributed engine's packed
    result transfer; ``wave_step`` fuses the same computation inline)."""
    return _pack_u32(alive, num_vertices)


def unpack_alive_u32(packed: np.ndarray, num_vertices: int) -> np.ndarray:
    """Host-side inverse of :func:`pack_alive_u32` — one bulk unpackbits."""
    packed = np.ascontiguousarray(np.asarray(packed).astype("<u4",
                                                            copy=False))
    bits = np.unpackbits(packed.view(np.uint8), axis=-1, bitorder="little")
    return bits[..., :num_vertices].astype(bool)


# ------------------------------------------------------------- fused step
class StepResult(NamedTuple):
    alive: jnp.ndarray    # [W, V] bool — the persistent lane buffer
    packed: jnp.ndarray   # [W, ceil(V/32)] uint32 bitmask of `alive`
    tti_lo: jnp.ndarray   # [W] int32 (I32_MAX when lane core is empty)
    tti_hi: jnp.ndarray   # [W] int32 (I32_MIN when lane core is empty)
    n_edges: jnp.ndarray  # [W] int32
    iters: jnp.ndarray    # scalar int32 — shared fixpoint iterations


def _wave_step_impl(tel: DeviceTEL, alive: jnp.ndarray, ts, te, k, h,
                    *, num_vertices: int, seg_pair, seg_vert) -> StepResult:
    alive, ea, iters = peel_to_fixpoint(
        tel, alive, ts, te, k, h, num_vertices=num_vertices,
        seg_pair=seg_pair, seg_vert=seg_vert)
    n_edges = jnp.sum(ea, axis=1, dtype=jnp.int32)
    tti_lo = jnp.min(jnp.where(ea, tel.t[None, :], _I32_MAX), axis=1)
    tti_hi = jnp.max(jnp.where(ea, tel.t[None, :], _I32_MIN), axis=1)
    return StepResult(alive, _pack_u32(alive, num_vertices),
                      tti_lo, tti_hi, n_edges, iters)


#: XLA-composite device step: peel W lanes to the fixpoint + TTI + stats +
#: bitmask pack in one jitted program.  ``ts``/``te``/``k``/``h`` are
#: per-lane [W] vectors — every lane may carry a different query's window
#: and thresholds.  ``alive`` is donated — the lane buffer is peeled in
#: place and handed back as ``StepResult.alive``.
wave_step = functools.partial(
    jax.jit, static_argnames=("num_vertices", "seg_pair", "seg_vert"),
    donate_argnums=(1,))(_wave_step_impl)

# non-donating twin for callers that reuse their alive buffer across calls
# (tcd_wave, benches); same trace, separate jit cache
_wave_step_nodonate = functools.partial(
    jax.jit, static_argnames=("num_vertices", "seg_pair",
                              "seg_vert"))(_wave_step_impl)


def make_wave_step_fn(tel: DeviceTEL, num_vertices: int, *,
                      seg_pair=None, seg_vert=None,
                      use_kernel: Optional[bool] = None,
                      interpret: Optional[bool] = None,
                      w_tile: int = 8, donate: bool = False,
                      vmem_budget_bytes: Optional[int] = None):
    """Build the device step for one TEL: ``step(alive, ts, te, k, h) ->
    StepResult``, with ``.backend`` ("pallas" | "xla") and ``.interpret``
    attributes.

    use_kernel=True routes through the fused Pallas peel-to-fixpoint
    kernel (interpret mode off-TPU unless ``interpret`` says otherwise);
    False through the XLA composite; None (default) auto-dispatches —
    compiled Pallas on TPU, XLA elsewhere.  A TEL whose VMEM working set
    exceeds the kernel budget falls back to the composite (the window
    truncation normally keeps E far below that).  ``donate=True`` donates
    the alive buffer (the pipeline's persistent lane slab); leave False
    when the caller reuses its buffer across calls.

    The two lowerings are bit-identical — alive, packed words, TTI lo/hi,
    edge counts and the iteration count all match exactly (seeded fuzz
    gate in tests/test_kernels.py).
    """
    from repro.kernels.segdeg.ops import on_tpu

    if use_kernel is None:
        use_kernel = on_tpu()
    if use_kernel:
        from repro.kernels.wave_peel.ops import (DEFAULT_VMEM_BUDGET,
                                                 make_fused_wave_step)

        budget = (DEFAULT_VMEM_BUDGET if vmem_budget_bytes is None
                  else int(vmem_budget_bytes))
        fused = make_fused_wave_step(tel, num_vertices, w_tile=w_tile,
                                     interpret=interpret, donate=donate,
                                     vmem_budget_bytes=budget)
        if fused is not None:
            return fused
    if seg_pair is None or seg_vert is None:
        from repro.kernels.segdeg.ref import banded_segsum_ref

        if seg_pair is None:
            seg_pair = functools.partial(banded_segsum_ref,
                                         num_segments=tel.num_pairs)
        if seg_vert is None:
            seg_vert = functools.partial(banded_segsum_ref,
                                         num_segments=num_vertices)
    inner = wave_step if donate else _wave_step_nodonate

    def step(alive, ts, te, k, h):
        return inner(tel, alive, ts, te, k, h, num_vertices=num_vertices,
                     seg_pair=seg_pair, seg_vert=seg_vert)

    step.backend = "xla"
    step.interpret = False
    return step


def tcd_wave(tel: DeviceTEL, alive: jnp.ndarray, ts, te, k, h,
             *, num_vertices: int, seg_pair=None, seg_vert=None,
             max_iters: int = 0, step_fn=None) -> WaveResult:
    """Batched TCD to the fixpoint.  alive: [Q, V] warm-start supersets;
    k/h: scalars or per-lane [Q] vectors (mixed-threshold waves).

    Pass ``step_fn`` (from :func:`make_wave_step_fn`) to route through a
    prebuilt device step — the fused Pallas kernel on TPU; otherwise the
    jitted XLA composite runs against ``seg_pair``/``seg_vert``.
    """
    if step_fn is not None:
        if max_iters:
            raise ValueError(
                "step_fn peels to the fixpoint; max_iters is only "
                "supported on the composite path")
        r = step_fn(alive, ts, te, k, h)
        n_verts = jnp.sum(r.alive, axis=1, dtype=jnp.int32)
        return WaveResult(r.alive, r.tti_lo, r.tti_hi, r.n_edges,
                          n_verts, r.iters)
    return _tcd_wave_xla(tel, alive, ts, te, k, h,
                         num_vertices=num_vertices, seg_pair=seg_pair,
                         seg_vert=seg_vert, max_iters=max_iters)


@functools.partial(jax.jit, static_argnames=("num_vertices", "seg_pair",
                                             "seg_vert", "max_iters"))
def _tcd_wave_xla(tel: DeviceTEL, alive: jnp.ndarray, ts, te, k, h,
                  *, num_vertices: int, seg_pair, seg_vert,
                  max_iters: int = 0) -> WaveResult:
    alive, ea, iters = peel_to_fixpoint(
        tel, alive, ts, te, k, h, num_vertices=num_vertices,
        seg_pair=seg_pair, seg_vert=seg_vert, max_iters=max_iters)
    n_edges = jnp.sum(ea, axis=1, dtype=jnp.int32)
    tti_lo = jnp.min(jnp.where(ea, tel.t[None, :], _I32_MAX), axis=1)
    tti_hi = jnp.max(jnp.where(ea, tel.t[None, :], _I32_MIN), axis=1)
    n_verts = jnp.sum(alive, axis=1, dtype=jnp.int32)
    return WaveResult(alive, tti_lo, tti_hi, n_edges, n_verts, iters)
