"""Distributed TCQ engine: shard_map semantics on degenerate + subprocess
multi-device meshes, plan invariants, and both degree-combine variants.

``dist_gate``-marked tests are the sharded-pipeline equivalence gate: the
sharded engine/service must be bit-identical to the single-device paths.
CI runs them with ``REPRO_DIST_GATE=1`` for the widened multi-mesh sweep;
they also run (narrower) in plain tier-1."""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import TCQEngine, TCQService
from repro.core.distributed import DistributedTCQ, ShardPlan, shard_graph
from repro.core.graph import _I32_MIN
from repro.core.oracle import peel_window
from repro.graphs import planted_cores, powerlaw_temporal

_GATE = os.environ.get("REPRO_DIST_GATE") == "1"


def _check_engine(g, mesh, combine, k, cells):
    eng = DistributedTCQ(g, mesh, combine=combine)
    ts = [c[0] for c in cells]
    te = [c[1] for c in cells]
    alive, lo, hi, ne, iters = eng.query_wave(ts, te, k)
    for i, (a, b) in enumerate(cells):
        em = peel_window(g, a, b, k)
        verts = (set(np.unique(np.concatenate(
            [g.src[em], g.dst[em]])).tolist()) if em.any() else set())
        got = set(np.flatnonzero(
            np.asarray(alive[i])[:g.num_vertices]).tolist())
        assert got == verts, (combine, i)
        if em.any():
            assert (int(lo[i]), int(hi[i])) == (int(g.t[em].min()),
                                                int(g.t[em].max()))
            assert int(ne[i]) == int(em.sum())


@pytest.mark.parametrize("combine", ["psum", "rs_ag"])
def test_wave_on_unit_mesh(combine):
    g = planted_cores(seed=3)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    _check_engine(g, mesh, combine, 3, [(1, 40), (5, 30), (10, 20), (1, 15)])


def test_pair_aligned_sharding_invariants():
    g = powerlaw_temporal(80, 600, 50, seed=1)
    for m in (2, 4, 8):
        plan = shard_graph(g, m)
        assert plan.src.shape[0] == m
        # every real edge appears exactly once; sentinels are inert
        real = plan.t != _I32_MIN
        assert int(real.sum()) == g.num_edges
        # pair-locality: local pair ids within [0, P_s)
        assert int(plan.pair_local[real].max()) < plan.num_pairs_shard
        # padded vertex space divisible by m
        assert plan.num_vertices % m == 0
        # capacity classes are pow2 so appends can land without reshape
        assert plan.e_cap & (plan.e_cap - 1) == 0
        assert plan.p_cap & (plan.p_cap - 1) == 0


def _real_edges(plan):
    """Multiset of real (src, dst, t) triples across all shards."""
    out = []
    for s in range(plan.num_shards):
        mask = plan.t[s] != _I32_MIN
        out.extend(zip(plan.src[s][mask].tolist(),
                       plan.dst[s][mask].tolist(),
                       plan.t[s][mask].tolist()))
    return sorted(out)


@pytest.mark.dist_gate
@pytest.mark.parametrize("seed", range(6 if _GATE else 2))
def test_shard_plan_append_matches_reshard(seed):
    """Epoch-versioned capacity-class TELs: appending edges and refreshing
    the plan in place must carry exactly the new graph's edges — the same
    multiset a from-scratch reshard would — and must keep array shapes
    (no recompile) while capacities suffice."""
    rng = np.random.default_rng(100 + seed)
    g = powerlaw_temporal(60, 400, 64, seed=seed)
    for m in (2, 4):
        plan = shard_graph(g, m)
        bounds0 = plan.bounds.copy()
        g2 = g
        for _ in range(4 if _GATE else 3):
            n = int(rng.integers(10, 80))
            u = rng.integers(0, 60, n)
            v = rng.integers(0, 60, n)
            keep = u != v
            t = rng.integers(1, 128, n)
            g2 = g2.add_edges(u[keep], v[keep], t[keep])
            shapes0 = (plan.src.shape, plan.pair_local.shape,
                       plan.hp_src.shape)
            same = plan.refresh(g2)
            assert plan.epoch == g2.epoch
            # frozen pair-key ownership: refresh never moves the cuts
            assert np.array_equal(plan.bounds, bounds0)
            if same:
                assert (plan.src.shape, plan.pair_local.shape,
                        plan.hp_src.shape) == shapes0
            want = sorted(zip(g2.src.tolist(), g2.dst.tolist(),
                              g2.t.tolist()))
            assert _real_edges(plan) == want
            # a from-scratch reshard carries the same edge multiset
            assert _real_edges(ShardPlan.build(g2, m)) == want
        # windowed extraction agrees with a direct host filter
        lo, hi = int(g2.t.min()), int(g2.t.max())
        ts, te = lo + (hi - lo) // 4, hi - (hi - lo) // 4
        src, dst, t, _ = plan.window_arrays(g2, ts, te)
        wmask = (g2.t >= ts) & (g2.t <= te)
        got = []
        for s in range(m):
            keepm = t[s] != _I32_MIN
            got.extend(zip(src[s][keepm].tolist(), dst[s][keepm].tolist(),
                           t[s][keepm].tolist()))
        assert sorted(got) == sorted(zip(g2.src[wmask].tolist(),
                                         g2.dst[wmask].tolist(),
                                         g2.t[wmask].tolist()))


_REQS = [dict(k=2, ts=5, te=60), dict(k=3, ts=10, te=70, h=2),
         dict(k=2, ts=1, te=40), dict(k=4, ts=20, te=80),
         dict(k=3, ts=30, te=75, h=1)]


def _assert_results_equal(got, want, ctx=""):
    assert len(got) == len(want), ctx
    for a, b in zip(got, want):
        aa, bb = a.by_tti(), b.by_tti()
        assert aa.keys() == bb.keys(), ctx
        for key in aa:
            assert np.array_equal(aa[key].vertices, bb[key].vertices), ctx
            assert aa[key].n_edges == bb[key].n_edges, ctx


@pytest.mark.dist_gate
@pytest.mark.parametrize("combine", ["psum", "rs_ag"])
def test_engine_mesh_unit_equivalence(combine):
    """1x1 mesh TCQEngine == plain TCQEngine: query_batch with mixed
    (k, h, window), plus re-query after an ingest epoch."""
    g = powerlaw_temporal(100, 900, 80, seed=7)
    plain = TCQEngine(g, cache=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = TCQEngine(g, cache=False, mesh=mesh, combine=combine)
    _assert_results_equal(eng.query_batch(_REQS), plain.query_batch(_REQS))
    dist = eng.stats()["distributed"]
    assert dist["combine"] == combine
    assert dist["pool_runs"] >= 1 and dist["device_steps"] >= 1
    # ingest an epoch; the sharded plan refreshes in place
    rng = np.random.default_rng(3)
    u, v = rng.integers(0, 100, 50), rng.integers(0, 100, 50)
    keep = u != v
    g2 = g.add_edges(u[keep], v[keep], rng.integers(1, 90, 50)[keep])
    plain.update_graph(g2)
    eng.update_graph(g2)
    _assert_results_equal(eng.query_batch(_REQS), plain.query_batch(_REQS))


@pytest.mark.dist_gate
def test_engine_mesh_kernel_rung_unit_equivalence():
    """The fused Pallas kernel routes as the per-shard local step on a
    unit mesh; results stay bit-identical to the plain engine whether or
    not the ladder demotes."""
    from repro.core.wave import ResilienceConfig

    g = planted_cores(seed=5)
    plain = TCQEngine(g, cache=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    reqs = _REQS[:3]
    want = plain.query_batch(reqs)
    eng = TCQEngine(g, cache=False, mesh=mesh, use_kernel=True)
    _assert_results_equal(eng.query_batch(reqs), want, "kernel")
    lad = TCQEngine(g, cache=False, mesh=mesh, use_kernel=True,
                    resilience=ResilienceConfig())
    _assert_results_equal(lad.query_batch(reqs), want, "ladder")


@pytest.mark.dist_gate
def test_service_mesh_unit_equivalence():
    """1x1 mesh TCQService == plain TCQService, with per-shard occupancy
    and collective-bytes surfaced in the pool log."""
    g = powerlaw_temporal(60, 400, 40, seed=2)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    svc_p = TCQService(g, cache=False)
    svc_d = TCQService(g, cache=False, mesh=mesh)
    reqs = [dict(k=2, ts=5, te=30), dict(k=3, ts=10, te=40, h=2),
            dict(k=1, ts=1, te=20), dict(k=2, ts=15, te=45)]
    for svc in (svc_p, svc_d):
        for r in reqs:
            svc.submit(r)
    out_p = {t.id: t for t in svc_p.run_until_idle()}
    out_d = {t.id: t for t in svc_d.run_until_idle()}
    assert out_p.keys() == out_d.keys()
    for tid in out_p:
        _assert_results_equal([out_d[tid].result], [out_p[tid].result])
    rec = svc_d.pool_log[0]
    assert rec["shard_occupancy"] and len(rec["shard_occupancy"]) == 1
    assert rec["collective_bytes"] == 0  # unit mesh: no wire traffic
    assert svc_d.stats["distributed"]["lane_shards"] == 1


_SUBPROCESS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys; sys.path.insert(0, "src")
import numpy as np, jax
from repro.core.distributed import DistributedTCQ
from repro.core.oracle import peel_window
from repro.graphs import planted_cores
g = planted_cores(seed=3)
mesh = jax.make_mesh((2, 4), ("data", "model"))
for combine in ("psum", "rs_ag"):
    eng = DistributedTCQ(g, mesh, combine=combine)
    ts, te, k = [1, 5, 10, 1], [40, 30, 20, 15], 3
    alive, lo, hi, ne, it = eng.query_wave(ts, te, k)
    for i in range(4):
        em = peel_window(g, ts[i], te[i], k)
        verts = set(np.unique(np.concatenate([g.src[em], g.dst[em]])).tolist()) if em.any() else set()
        got = set(np.flatnonzero(np.asarray(alive[i])[:g.num_vertices]).tolist())
        assert got == verts, (combine, i)
print("OK")
"""


def test_wave_on_2x4_mesh_subprocess():
    """Real multi-device shard_map semantics (8 fake CPU devices require a
    fresh process: jax locks the device count at first init)."""
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


_MESH_EQUIV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys; sys.path.insert(0, "src")
import json
import numpy as np, jax
from repro.core import TCQEngine, TCQService
from repro.graphs import powerlaw_temporal

cases = json.loads(sys.argv[1])
g = powerlaw_temporal(100, 900, 80, seed=7)
reqs = [dict(k=2, ts=5, te=60), dict(k=3, ts=10, te=70, h=2),
        dict(k=2, ts=1, te=40), dict(k=4, ts=20, te=80),
        dict(k=3, ts=30, te=75)]

def check(got, want, ctx):
    assert len(got) == len(want), ctx
    for a, b in zip(got, want):
        aa, bb = a.by_tti(), b.by_tti()
        assert aa.keys() == bb.keys(), ctx
        for key in aa:
            assert np.array_equal(aa[key].vertices, bb[key].vertices), ctx
            assert aa[key].n_edges == bb[key].n_edges, ctx

plain = TCQEngine(g, cache=False)
want = plain.query_batch(reqs)
for L, M, combine in cases:
    mesh = jax.make_mesh((L, M), ("data", "model"))
    eng = TCQEngine(g, cache=False, mesh=mesh, combine=combine)
    check(eng.query_batch(reqs), want, (L, M, combine, "batch"))
    d = eng.stats()["distributed"]
    assert (d["lane_shards"], d["model_shards"]) == (L, M)
    assert M == 1 or d["collective_bytes"] > 0, (L, M, combine)

# service: mid-flight admission + ingest across epochs
rng = np.random.default_rng(0)
u, v = rng.integers(0, 100, 40), rng.integers(0, 100, 40)
keep = u != v
extra = (u[keep], v[keep], rng.integers(1, 90, 40)[keep])
sreqs = [dict(k=2, ts=5, te=55), dict(k=3, ts=8, te=60),
         dict(k=2, ts=12, te=64, h=2), dict(k=3, ts=3, te=50)]
late = [dict(k=2, ts=6, te=58), dict(k=4, ts=10, te=62)]

def run_service(mesh):
    kw = {} if mesh is None else {"mesh": mesh}
    svc = TCQService(g, cache=False, **kw)
    for r in sreqs:
        svc.submit(r)
    fired = []
    def poll(s):
        if not fired:
            fired.append(1)
            s.push_edges(*extra)      # new epoch lands mid-serve
            for r in late:            # arrivals while the pool runs
                s.submit(r)
    out = svc.run_until_idle(poll)
    while svc.pending:
        out += svc.run_until_idle()
    assert svc.epoch == 1
    return {t.id: t for t in out}

base = run_service(None)
for L, M, combine in cases:
    mesh = jax.make_mesh((L, M), ("data", "model"))
    got = run_service(mesh)
    assert base.keys() == got.keys(), (L, M)
    for tid in base:
        check([got[tid].result], [base[tid].result], (L, M, "svc", tid))
print("OK")
"""

_DEFAULT_CASES = [[1, 2, "psum"], [2, 2, "rs_ag"]]
_GATE_CASES = [[1, 2, "psum"], [1, 2, "rs_ag"], [2, 2, "psum"],
               [2, 2, "rs_ag"], [1, 8, "rs_ag"], [8, 1, "psum"]]


@pytest.mark.dist_gate
def test_mesh_equivalence_subprocess():
    """Sharded engine + service vs single-device, on real multi-device
    meshes (8 fake CPU devices need a fresh process: jax locks the device
    count at first init).  Mixed (k, h, window) batches, mid-flight
    admission, and ingest across epochs must all be bit-identical.
    REPRO_DIST_GATE=1 widens the mesh/combine sweep."""
    cases = _GATE_CASES if _GATE else _DEFAULT_CASES
    out = subprocess.run(
        [sys.executable, "-c", _MESH_EQUIV, json.dumps(cases)],
        capture_output=True, text=True, cwd="/root/repo", timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


_SHARD_FAULT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys; sys.path.insert(0, "src")
import jax
from repro.core import ResilienceConfig, TCQService
from repro.core.faultinject import FaultPlan, FaultyStep
from repro.graphs import powerlaw_temporal

g = powerlaw_temporal(64, 192, 128, seed=9)
lo, hi = g.span
third = (hi - lo) // 3
reqs = []                      # two disjoint groups -> two pools/ladders
for base in (lo, lo + 2 * third):
    for i in range(3):
        reqs.append(dict(k=2, ts=int(base + i),
                         te=int(min(base + third - i, hi))))


def digest(tickets):
    return [sorted((k, tuple(c.vertices.tolist()), c.n_edges)
                   for k, c in t.result.by_tti().items())
            for t in sorted(tickets, key=lambda t: t.id)]


mesh = jax.make_mesh((8, 1), ("data", "model"))


def drain(wrapper):
    svc = TCQService(g, mesh=mesh, use_kernel=True, cache=False,
                     retain_snapshots=False,
                     resilience=ResilienceConfig(seed=0,
                                                 rung_wrapper=wrapper))
    for r in reqs:
        svc.submit(dict(r))
    return svc, digest(svc.run_until_idle())


_, want = drain(None)

state = {"armed": True}


def one_shot(name, fn):
    # ladders build per window pool: arm exactly one pool's kernel rung
    if name == "pallas" and state["armed"]:
        state["armed"] = False
        return FaultyStep(fn, FaultPlan(fail_at=(0,)))
    return fn


svc, got = drain(one_shot)
demo = [e for e in svc.engine.resilience_events()
        if e.get("reason") == "error"]
assert not state["armed"], "no pallas rung was ever built"
assert len(demo) == 1, f"expected exactly one demotion: {demo}"
assert got == want, "sharded drain diverged after per-shard rung fault"
backends = [p.get("backend") for p in svc.pool_log]
assert "pallas" in backends, f"healthy pool left the kernel: {backends}"
print("OK")
"""


@pytest.mark.dist_gate
def test_sharded_rung_fault_demotes_one_pool_subprocess():
    """Per-shard kernel fault on an 8-device lane-sharded mesh: only the
    faulted pool's ShardedDegradationLadder demotes (one event, reason
    'error'), the other pool stays on the fused kernel, and the whole
    drain is bit-identical to the fault-free sharded run."""
    out = subprocess.run([sys.executable, "-c", _SHARD_FAULT],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_dryrun_smoke_subprocess():
    """The dry-run entrypoint itself (reduced configs, real 512-device mesh
    construction) — proves the mesh + lowering pipeline end to end."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
         "--arch", "gemma2-2b", "--shape", "train_4k,decode_32k",
         "--mesh", "both"],
        capture_output=True, text=True, cwd="/root/repo", timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"})
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-1500:])
    assert "0 failed" in out.stdout
