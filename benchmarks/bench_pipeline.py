"""Serial engine vs device-resident pipelined wave engine.

Measures the wave pipeline's claims against the paper-faithful serial
engine on the same schedule, same windowed TEL:

  * wall time — the pipelined engine packs up to W schedule cells into
    one fused device step and overlaps host pruning bookkeeping with
    device compute (depth-D slot ring);
  * host sync counts — one blocking device_get per step vs one per
    evaluated cell plus one per discovered core;
  * device->host bytes per step — packed uint32 bitmasks (O(W*V/32)
    words) vs per-core [V] bool masks.

(The seed stepwise engine that used to anchor this bench was retired
after PR 2 — its numbers live on in the BENCH_wave.json history.)

The reference workload is a fixed window of the CPU-scaled collegemsg
analogue (deterministic — no query search loop), chosen to be
dispatch/transfer-bound like the paper's result-proportional regime.
Both modes' result sets are compared core-by-core and the run raises on
any divergence, so ``python -m benchmarks.run`` exits non-zero if the
pipelined engine ever drifts from the serial reference — the bench
doubles as a regression gate.  Emits rows for
benchmarks/results/bench_pipeline.json; run.py folds the same rows into
the repo-root BENCH_wave.json trajectory file.
"""

from __future__ import annotations

from benchmarks.common import (GRAPH_K, assert_cores_equal, emit, engine,
                               graph, timeit)

SPAN_UTS = 120      # unique timestamps in the reference window
START_UTS = 100     # fixed window start (index into unique_ts)


def reference_window(name: str):
    uts = graph(name).unique_ts
    i0 = min(START_UTS, max(0, uts.size - SPAN_UTS - 1))
    return int(uts[i0]), int(uts[min(i0 + SPAN_UTS, uts.size - 1)])


def run(name: str = "collegemsg", wave: int = 8, repeat: int = 3):
    eng = engine(name)
    k = GRAPH_K[name]
    ts, te = reference_window(name)
    rows = []
    by_mode = {}
    results = {}
    for mode in ("serial", "wave"):
        kw = {} if mode == "serial" else {"mode": "wave", "wave": wave}
        fn = lambda: eng.query(k, ts, te, **kw)  # noqa: E731
        res = fn()                       # warm the compile caches
        results[mode] = res
        t = timeit(fn, repeat=repeat)
        s = res.stats
        row = {
            "bench": "pipeline", "graph": name, "mode": mode, "wave": wave,
            "ts": ts, "te": te, "k": k, "t_s": t, "n_cores": len(res),
            "device_steps": s.device_steps, "cells": s.cells_evaluated,
            "duplicates": s.duplicates, "host_syncs": s.host_syncs,
            "bytes_synced": s.bytes_synced,
            "syncs_per_step": s.host_syncs / max(1, s.device_steps),
            "bytes_per_step": s.bytes_synced / max(1, s.device_steps),
            "lane_refills": s.lane_refills, "peel_iters": s.peel_iters,
        }
        rows.append(row)
        by_mode[mode] = row
    # regression gate: the pipelined engine must return exactly the
    # serial engine's result set on the reference workload — a raise
    # here makes `python -m benchmarks.run` exit non-zero
    assert_cores_equal(results["wave"], results["serial"],
                       ctx=f"wave vs serial on {name}")
    se, pl = by_mode["serial"], by_mode["wave"]
    rows.append({
        "bench": "pipeline_summary", "graph": name, "wave": wave,
        "equivalent": True,     # the gate above raised otherwise
        "speedup_wave_vs_serial": se["t_s"] / pl["t_s"],
        "sync_reduction": se["host_syncs"] / max(1, pl["host_syncs"]),
        "bytes_per_step_reduction":
            se["bytes_per_step"] / max(1e-9, pl["bytes_per_step"]),
    })
    emit("bench_pipeline", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
