from repro.checkpoint.manager import CheckpointManager, reshard  # noqa: F401
