from repro.optim.optimizers import (  # noqa: F401
    AdamW,
    Adafactor,
    make_optimizer,
    opt_state_pspecs,
)
from repro.optim.compression import (  # noqa: F401
    compressed_psum,
    compressed_psum_exact,
    dequantize_int8,
    quantize_int8,
)
