"""Llama-4 Scout 17B-active / 16-expert [hf:meta-llama/Llama-4-Scout-17B-16E].

MoE with top-1 routed + always-on shared expert ("early fusion" of expert
streams).  48L, d=5120, 40 heads (GQA kv=8), d_ff(expert)=8192, vocab 202k.
"""
from repro.models.config import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202_048,
    act="silu", glu=True, pos="rope", rope_theta=500_000.0,
    tie_embeddings=False,
    moe=MoECfg(num_experts=16, top_k=1, d_expert=8192, every=1,
               shared_expert=True),
    max_seq=32_768,
    notes="MoE top-1 + shared expert; full attention => long_500k skipped",
)
