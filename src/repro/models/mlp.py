"""Dense MLP variants: SwiGLU / GeGLU / plain (GPT-BigCode) / RWKV channel-mix."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import activation


def mlp(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    if cfg.glu:
        return (activation(x @ p["wg"], cfg.act) * (x @ p["wu"])) @ p["wd"]
    return activation(x @ p["wu"], cfg.act) @ p["wd"]


def rwkv_channel_mix(p: dict, x: jnp.ndarray, shift_state, cfg):
    """RWKV channel-mix with token shift.  x: [B,S,d]; shift_state: [B,d]
    (last token of the previous step for decode).  Returns (out, new_state)."""
    b, s, d = x.shape
    prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    xx = prev - x
    xk = x + xx * p["mu_k"]
    xr = x + xx * p["mu_r"]
    k = activation(xk @ p["wu"], "relu_sq")
    r = jnp.clip(xr @ p["wr"], -60.0, 60.0)
    out = (k @ p["wd"]) * (1.0 / (1.0 + jnp.exp(-r)))
    return out, x[:, -1, :]
