"""Whisper small [arXiv:2212.04356] — encoder-decoder backbone.  The conv
audio frontend is STUBBED: input_specs() provides precomputed frame
embeddings; the decoder is a standard causal LM with cross-attention."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab=51_865,
    act="gelu", glu=False, norm="layernorm", pos="learned", qkv_bias=True,
    tie_embeddings=True, encoder_layers=12,
    max_seq=32_768,
    notes="enc-dec: decode cells run (decoder KV + cross cache); long skipped",
)
