"""Qwen2-VL 72B backbone [arXiv:2409.12191].

80L, d=8192, 64 heads (GQA kv=8), d_ff=29568, vocab 152064.  M-RoPE with
temporal/height/width position streams; dynamic-resolution vision frontend is
STUBBED — input_specs() feeds precomputed patch embeddings + (3,B,S) positions.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29_568, vocab=152_064,
    act="silu", glu=True, pos="mrope", rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24), qkv_bias=True,
    tie_embeddings=False, input_mode="embeds",
    max_seq=32_768,
    notes="M-RoPE VLM backbone, patch embeds stubbed; long_500k skipped",
)
