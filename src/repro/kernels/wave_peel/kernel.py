"""Pallas TPU kernel: the whole peel-to-fixpoint wave step, fused.

The XLA composite (`core/wave.py`'s ``peel_to_fixpoint`` chain) runs the
fixpoint loop at HBM bandwidth: every iteration re-materializes the
[W, E] edge-activity mask, the [P, W] pair counts and the [2P, W] pair
contributions as separate fusion outputs.  This kernel runs the *entire*
fixpoint loop per W-tile with every intermediate resident in VMEM:

  grid = (W_tiles,)     one program owns a w_tile x V slab of lane state

  * per-lane (ts, te, k, h) ride in SMEM via scalar prefetch, so a
    mixed-threshold multi-tenant wave shares one launch;
  * window masking, edge activity, the banded pair-count, the
    h-threshold, the vertex-degree accumulation and the k-survivor test
    are one loop body — nothing crosses HBM between iterations;
  * both segment reductions exploit the ArrayTEL canonical sort: a
    sorted-segment sum is a *prefix-sum range difference*, so an int32
    cumsum along the edge axis plus two boundary gathers (host-derived
    ``segment_bounds`` tables, also prefetched) replaces the scatter /
    one-hot matmul entirely;
  * on the final iteration the kernel emits TTI lo/hi, per-lane live
    edge counts and the uint32 bitmask pack directly, so the step's
    HBM traffic is the TEL (read once per W-tile), the alive slab
    (read + written once) and the packed/scalar outputs — independent
    of the iteration count.

Segment sums here count *booleans*, so int32 prefix sums are exact and
the kernel is bit-identical to the f32 composite (small integers are
exact in f32).  Per-tile fixpoint iteration counts can only be <= the
composite's global count, and a converged lane is invariant under extra
iterations, so max-over-tiles equals the composite's ``iters`` exactly
(asserted by the seeded fuzz gate in tests/test_kernels.py).

Validated on CPU with interpret=True against the XLA composite.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU grid spec (scalar prefetch); interpret mode also uses it
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min


def segment_bounds(seg_ids_host, num_segments: int):
    """Host-side band table for a *sorted* segment-id array: for segment
    s, its rows are exactly ``[starts[s], ends[s])``.  Sentinel ids >=
    ``num_segments`` (capacity padding) sort past every real segment and
    fall outside every range."""
    segs = np.asarray(seg_ids_host)
    idx = np.arange(num_segments, dtype=np.int64)
    starts = np.searchsorted(segs, idx, side="left").astype(np.int32)
    ends = np.searchsorted(segs, idx, side="right").astype(np.int32)
    return starts, ends


def _banded_count(x, lo, hi):
    """x: [w, N] int32; lo/hi: [S] row ranges (sorted segments).
    Returns [w, S] int32 per-segment sums via prefix-sum differences."""
    cum = jnp.cumsum(x, axis=1)
    upper = jnp.take(cum, jnp.maximum(hi - 1, 0), axis=1)
    lower = jnp.take(cum, jnp.maximum(lo - 1, 0), axis=1)
    lower = jnp.where((lo > 0)[None, :], lower, 0)
    return jnp.where((hi > lo)[None, :], upper - lower, 0)


def _kernel(ts_ref, te_ref, k_ref, h_ref,           # SMEM scalar prefetch
            t_ref, src_ref, dst_ref, hpp_ref,       # TEL tables [1, .]
            ps_ref, pe_ref, vs_ref, ve_ref,         # band tables [1, .]
            alive_ref,                              # [w_tile, V32] in
            alive_out_ref, packed_ref, lo_ref, hi_ref, ne_ref, it_ref,
            *, w_tile: int):
    q = pl.program_id(0)
    base = q * w_tile
    ts = ts_ref[pl.ds(base, w_tile)].reshape(w_tile, 1)
    te = te_ref[pl.ds(base, w_tile)].reshape(w_tile, 1)
    kk = k_ref[pl.ds(base, w_tile)].reshape(w_tile, 1)
    hh = h_ref[pl.ds(base, w_tile)].reshape(w_tile, 1)

    t = t_ref[0, :]
    src = src_ref[0, :]
    dst = dst_ref[0, :]
    hpp = hpp_ref[0, :]
    ps, pe = ps_ref[0, :], pe_ref[0, :]
    vs, ve = vs_ref[0, :], ve_ref[0, :]

    # loop-invariant: sentinel edges carry t = int32 min, below every window
    win = (t[None, :] >= ts) & (t[None, :] <= te)

    def cond(state):
        return state[2]

    def body(state):
        cur, _, _, it = state
        ea = win & jnp.take(cur, src, axis=1) & jnp.take(cur, dst, axis=1)
        paircnt = _banded_count(ea.astype(jnp.int32), ps, pe)    # [w, P]
        pairact = (paircnt >= hh).astype(jnp.int32)
        contrib = jnp.take(pairact, hpp, axis=1)                 # [w, 2P]
        deg = _banded_count(contrib, vs, ve)                     # [w, V32]
        new = cur & (deg >= kk)
        return new, ea, jnp.any(new != cur), it + jnp.int32(1)

    alive0 = alive_ref[...]
    ea0 = jnp.zeros(win.shape, dtype=jnp.bool_)
    alive, ea, _, iters = jax.lax.while_loop(
        cond, body, (alive0, ea0, jnp.bool_(True), jnp.int32(0)))

    alive_out_ref[...] = alive
    ne_ref[...] = jnp.sum(ea, axis=1, dtype=jnp.int32).reshape(w_tile, 1)
    lo_ref[...] = jnp.min(jnp.where(ea, t[None, :], _I32_MAX),
                          axis=1).reshape(w_tile, 1)
    hi_ref[...] = jnp.max(jnp.where(ea, t[None, :], _I32_MIN),
                          axis=1).reshape(w_tile, 1)
    it_ref[0, 0] = iters
    # uint32 bitmask pack, LSB-first (engine._pack_u32 layout): bit sums of
    # distinct powers of two are exact mod 2^32 in int32, bitcast in wrapper
    v32 = alive.shape[1]
    bits = alive.astype(jnp.int32).reshape(w_tile, v32 // 32, 32)
    shift = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 32), 2)
    packed_ref[...] = jnp.sum(bits << shift, axis=2, dtype=jnp.int32)


def wave_peel_pallas(ts, te, k, h, t2, src2, dst2, hpp2,
                     ps2, pe2, vs2, ve2, alive,
                     *, w_tile: int, interpret: bool):
    """Raw fused call over pre-padded arrays.

    ts/te/k/h: [W_pad] int32 (W_pad a multiple of w_tile); t2/src2/dst2:
    [1, E_pad]; hpp2: [1, HP_pad]; ps2/pe2: [1, P]; vs2/ve2: [1, V32];
    alive: [W_pad, V32] bool with V32 a multiple of 32.

    Returns (alive [W_pad, V32] bool, packed [W_pad, V32//32] int32,
    lo/hi/ne [W_pad, 1] int32, iters [W_tiles, 1] int32).
    """
    w_pad, v32 = alive.shape
    n_tiles = w_pad // w_tile
    e_pad = t2.shape[1]
    hp_pad = hpp2.shape[1]
    p_dim = ps2.shape[1]

    full = lambda w: pl.BlockSpec((1, w), lambda q, *pref: (0, 0))  # noqa: E731
    lane = lambda w: pl.BlockSpec((w_tile, w), lambda q, *pref: (q, 0))  # noqa: E731

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(n_tiles,),
        in_specs=[
            full(e_pad), full(e_pad), full(e_pad),   # t, src, dst
            full(hp_pad),                            # hp_pair
            full(p_dim), full(p_dim),                # pair starts/ends
            full(v32), full(v32),                    # vertex starts/ends
            lane(v32),                               # alive
        ],
        out_specs=[
            lane(v32),                               # alive out
            lane(v32 // 32),                         # packed
            lane(1), lane(1), lane(1),               # lo, hi, ne
            pl.BlockSpec((1, 1), lambda q, *pref: (q, 0)),  # iters
        ],
    )
    out_shape = [
        jax.ShapeDtypeStruct((w_pad, v32), jnp.bool_),
        jax.ShapeDtypeStruct((w_pad, v32 // 32), jnp.int32),
        jax.ShapeDtypeStruct((w_pad, 1), jnp.int32),
        jax.ShapeDtypeStruct((w_pad, 1), jnp.int32),
        jax.ShapeDtypeStruct((w_pad, 1), jnp.int32),
        jax.ShapeDtypeStruct((n_tiles, 1), jnp.int32),
    ]
    return pl.pallas_call(
        functools.partial(_kernel, w_tile=w_tile),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(ts, te, k, h, t2, src2, dst2, hpp2, ps2, pe2, vs2, ve2, alive)
