"""RWKV6 ("Finch") time-mix: gated linear recurrence with data-dependent
per-channel decay (arXiv:2404.05892), in chunked matmul form.

State recurrence (per head, hd x hd state S):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(w0 + lora_w(x_t))) in (0,1) per channel.

The chunked form computes intra-chunk contributions as causal matmuls with
cumulative-decay rescaling (GLA-style), carrying S across chunks — linear in
sequence length, MXU-friendly, and exactly equal to the step recurrence
(validated in tests against the naive scan).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import group_rmsnorm


def _ddlerp(p, x, prev):
    """Data-dependent token-shift interpolation for the 5 streams (r,k,v,w,g)."""
    xx = prev - x
    base = x + xx * p["mu_x"]
    lora = jnp.tanh(base @ p["mix_a"])            # [B,S,5*L]
    lora = lora.reshape(*lora.shape[:-1], 5, -1)  # [B,S,5,L]
    adj = jnp.einsum("bsfl,fld->bsfd", lora, p["mix_b"])
    mixed = x[..., None, :] + xx[..., None, :] * (p["mu"] + adj)
    return [mixed[..., i, :] for i in range(5)]   # r,k,v,w,g


def rwkv_time_mix(p: dict, x: jnp.ndarray, cfg, state: Tuple,
                  chunk: int = 64):
    """x: [B,S,d].  state: (wkv [B,H,hd,hd] f32, shift [B,d]).
    Returns (out [B,S,d], new_state)."""
    r_cfg = cfg.rwkv
    b, s, d = x.shape
    hd = r_cfg.head_dim
    h = d // hd
    wkv0, shift = state
    prev = jnp.concatenate([shift[:, None, :], x[:, :-1, :]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(p, x, prev)

    r = (xr @ p["wr"]).reshape(b, s, h, hd)
    k = (xk @ p["wk"]).reshape(b, s, h, hd)
    v = (xv @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay, log-space: logw in (-inf, 0)
    dec = p["w0"] + jnp.tanh(xw @ p["dec_a"]) @ p["dec_b"]
    logw = -jnp.exp(dec.astype(jnp.float32)).reshape(b, s, h, hd)
    u = p["u"].astype(jnp.float32)                # [H, hd]

    # ---- chunked evaluation ----
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    c = chunk

    def per_chunk(carry, xs):
        S = carry                                  # [B,H,hd,hd] f32
        rc, kc, vc, lw = xs                        # [B,c,H,hd] each
        rc = rc.astype(jnp.float32)
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        cl = jnp.cumsum(lw, axis=1)                # inclusive cumulative logw
        cl_ex = cl - lw                            # exclusive
        # inter-chunk: y_t += (r_t * exp(cl_ex_t)) @ S   (cl_ex <= 0: bounded)
        r_dec = rc * jnp.exp(cl_ex)
        y = jnp.einsum("bchi,bhij->bchj", r_dec, S)
        # intra-chunk (strictly causal s' < t):
        #   A[t,s'] = sum_i r[t,i] k[s',i] exp(cl_ex[t,i] - cl[s',i])
        # pairwise-exact form: every unmasked exponent is <= 0 (cl decreases),
        # and masked pairs are clamped before exp — no overflow is possible,
        # unlike the factored (r e^{cl})·(k e^{-cl}) form.
        mask = jnp.tril(jnp.ones((c, c), bool), -1)
        expo = (cl_ex.transpose(0, 2, 1, 3)[:, :, :, None, :]
                - cl.transpose(0, 2, 1, 3)[:, :, None, :, :])  # [B,H,t,s,hd]
        expo = jnp.where(mask[None, None, :, :, None], expo, -jnp.inf)
        att = jnp.einsum("bhti,bhsi,bhtsi->bhts",
                         rc.transpose(0, 2, 1, 3), kc.transpose(0, 2, 1, 3),
                         jnp.exp(expo))
        y = y + jnp.einsum("bhts,bshj->bthj", att, vc)
        # bonus current-token term: y_t += sum_i r[t,i] u[i] k[t,i] v[t,:]
        bonus = jnp.einsum("bchi,hi,bchi->bch", rc, u, kc)
        y = y + bonus[..., None] * vc
        # state update: S' = diag(prod w) S + sum_s' diag(exp(cl_end-cl_s')) k v
        cl_end = cl[:, -1][:, :, :, None]          # [B,H,hd,1]
        k_tail = kc * jnp.exp(cl[:, -1][:, None] - cl)
        S = jnp.exp(cl_end) * S + jnp.einsum("bchi,bchj->bhij", k_tail, vc)
        return S, y

    xs = tuple(a.reshape(b, n_chunks, c, h, hd).transpose(1, 0, 2, 3, 4)
               for a in (r, k, v, logw))
    S_fin, ys = jax.lax.scan(per_chunk, wkv0.astype(jnp.float32), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * c, h, hd)[:, :s]
    y = group_rmsnorm(y, p["ln_x"].reshape(h, hd)).reshape(b, s, d)
    out = (y.astype(x.dtype) * g) @ p["wo"]
    return out, (S_fin.astype(wkv0.dtype), x[:, -1, :])


def rwkv_time_mix_step(p: dict, x: jnp.ndarray, cfg, state: Tuple):
    """Single-token decode step (exact recurrence). x: [B,1,d]."""
    return rwkv_time_mix(p, x, cfg, state, chunk=1)
