"""Production mesh construction.

Single pod: 16x16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — `pod` is the
outermost (DCN-connected) axis and carries pure data parallelism plus the
query-wave axis of the TCQ engine.

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh over the locally available devices (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Mesh axes carrying the batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
