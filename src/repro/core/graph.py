"""ArrayTEL: the TPU-native re-think of the paper's Temporal Edge List.

The paper's TEL is three dimensions of doubly-linked lists (timeline, source
list, destination list) supporting O(1) edge deletion on a CPU.  Pointers do
not exist on a TPU; the idiomatic equivalent is a structure-of-arrays with
boolean liveness masks:

  * edges are stored once, canonically sorted by ``(pair_id, t)`` so that the
    edge->pair segment reduction (distinct-neighbour degree semantics) sees
    *sorted* segment ids — which is what lets the Pallas kernel turn the
    reduction into a banded one-hot matmul on the MXU;
  * the "timeline" is the sorted unique-timestamp table plus per-edge
    timestamps; window truncation becomes a vectorized compare (or, in the
    time-sorted permutation kept for kernels, a contiguous rank range);
  * "deletion" is a mask update; the memory bound of the paper (space of the
    initial TEL only, no intermediates) is preserved: peeling state is one
    bool per vertex per in-flight query.

Host-side construction is numpy; ``device_tel()`` ships immutable arrays to
the accelerator once per graph *epoch*.  Streaming appends
(:meth:`TemporalGraph.add_edges`) are an incremental sorted-run merge —
O(E + B log B) for a batch of B edges, not a full O(E log E) re-sort — and
bump the graph's ``epoch`` so downstream caches (the engine's window-TEL
cache, the service's admission pinning) can tell snapshots apart.  Device
buffers may be padded to power-of-two *capacities* with never-active
sentinel rows, so a growing graph reuses compiled programs until it
outgrows its capacity class (capacity-doubling, amortized O(1) recompiles).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, NamedTuple, Optional, Tuple

import numpy as np

_I32_MIN = np.iinfo(np.int32).min
_I32_MAX = np.iinfo(np.int32).max

# Monotonic graph identity.  ``id(graph)`` is reused after GC, so caches
# keyed on it can silently serve closures built for a dead graph; every
# TemporalGraph instead draws a process-unique uid at construction.
_GRAPH_UID = itertools.count()


class GraphIngestError(ValueError):
    """A malformed edge batch was rejected before touching the TEL.

    The canonical ArrayTEL layout has hard representational invariants —
    vertex ids pack into ``(lo << 32) | hi`` 64-bit pair keys, timestamps
    and ids are stored int32, and the merge-append's composite sort key
    biases timestamps by ``int32 min`` — so NaN, fractional, negative-id
    or out-of-int32 inputs would not fail loudly: they would silently
    corrupt the sort invariant every engine and cache is built on.
    ``from_edges``/``add_edges`` raise this instead.
    """


def _validate_edge_batch(u, v, t, *, strict: bool = False,
                         num_vertices: Optional[int] = None):
    """Validate and canonicalize one (u, v, t) batch to int64 1-D arrays.

    Always rejected (these silently corrupt the TEL otherwise): non-numeric
    or non-finite values, fractional values, negative vertex ids, ids or
    timestamps outside the int32 range (ids must also leave the pair-key
    packing unambiguous), a timestamp equal to the ``int32 min`` sentinel,
    and — when ``num_vertices`` is given — ids >= num_vertices.

    ``strict=True`` additionally rejects self-loops and negative
    timestamps; by default both are legal (self-loops are dropped — they
    never contribute to distinct-neighbour degree — and late/negative
    timestamps are an explicitly supported streaming regime).
    """
    cols = []
    for name, col in (("u", u), ("v", v), ("t", t)):
        a = np.asarray(col)
        if a.dtype == object or not (
                np.issubdtype(a.dtype, np.integer)
                or np.issubdtype(a.dtype, np.floating)
                or np.issubdtype(a.dtype, np.bool_)):
            raise GraphIngestError(
                f"edge batch column {name!r} has non-numeric dtype "
                f"{a.dtype}")
        if np.issubdtype(a.dtype, np.floating):
            if not np.all(np.isfinite(a)):
                raise GraphIngestError(
                    f"edge batch column {name!r} contains NaN/inf")
            if a.size and np.any(a != np.floor(a)):
                raise GraphIngestError(
                    f"edge batch column {name!r} contains fractional "
                    "values")
        cols.append(a.astype(np.int64).ravel())
    u64, v64, t64 = cols
    if not (u64.shape == v64.shape == t64.shape):
        raise GraphIngestError("u, v, t must have identical shapes")
    for name, a in (("u", u64), ("v", v64)):
        if a.size and int(a.min()) < 0:
            raise GraphIngestError(
                f"edge batch column {name!r} contains negative vertex ids")
        if a.size and int(a.max()) > _I32_MAX:
            raise GraphIngestError(
                f"edge batch column {name!r} exceeds the int32 id range")
    if num_vertices is not None and u64.size:
        mx = max(int(u64.max()), int(v64.max()))
        if mx >= int(num_vertices):
            raise GraphIngestError(
                f"vertex id {mx} out of range for num_vertices="
                f"{int(num_vertices)}")
    if t64.size:
        # t == int32 min is the capacity-padding sentinel (outside every
        # representable window); a real edge carrying it would be dropped
        # by the window masks as if it were padding
        if int(t64.min()) <= _I32_MIN or int(t64.max()) > _I32_MAX:
            raise GraphIngestError(
                "edge batch timestamps outside the representable int32 "
                "range (int32 min is reserved as the padding sentinel)")
    if strict:
        if np.any(u64 == v64):
            raise GraphIngestError("edge batch contains self-loops "
                                   "(strict ingest)")
        if t64.size and int(t64.min()) < 0:
            raise GraphIngestError("edge batch contains negative "
                                   "timestamps (strict ingest)")
    return u64, v64, t64


def pow2_capacity(n: int, floor: int = 128) -> int:
    """Smallest power of two >= max(n, floor) — the capacity classes used
    for padded device buffers (and the window-TEL edge buckets)."""
    return max(floor, 1 << max(0, int(n) - 1).bit_length())


def _merge_sorted_unique(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Union of two sorted-unique int arrays in O(|a| + |b| log |a|)."""
    if b.size == 0:
        return a
    if a.size == 0:
        return b
    pos_a = np.searchsorted(a, b)
    present = (pos_a < a.size) & (a[np.minimum(pos_a, a.size - 1)] == b)
    fresh = b[~present]
    merged = np.empty(a.size + fresh.size, dtype=a.dtype)
    pos = np.searchsorted(a, fresh) + np.arange(fresh.size)
    mask = np.ones(merged.size, dtype=bool)
    mask[pos] = False
    merged[pos] = fresh
    merged[mask] = a
    return merged


class DeviceTEL(NamedTuple):
    """Immutable device-resident temporal edge list (pytree of arrays).

    Shapes: E edges, P distinct vertex pairs ("links"), V vertices.
    Edges are sorted by (pair_id, t); pairs are sorted by (u, v) with u < v;
    half-pairs (2P incidences) are sorted by their vertex id.

    Arrays may be *capacity padded* (see :meth:`TemporalGraph.tel_arrays`):
    sentinel edges carry ``t = int32 min`` (outside every representable
    window) and ``pair_id`` equal to the padded pair count, sentinel
    half-pairs point at the padded vertex count — both are dropped by the
    segment reductions, so padded and exact TELs peel identically while
    the padded shapes keep compiled programs reusable across epochs.
    """

    src: np.ndarray        # [E] int32
    dst: np.ndarray        # [E] int32
    t: np.ndarray          # [E] int32 timestamps
    pair_id: np.ndarray    # [E] int32, sorted ascending
    pair_u: np.ndarray     # [P] int32 (u < v)
    pair_v: np.ndarray     # [P] int32
    hp_src: np.ndarray     # [2P] int32, sorted ascending (vertex of incidence)
    hp_pair: np.ndarray    # [2P] int32 (pair of incidence)
    time_perm: np.ndarray  # [E] int32: argsort(t) — timeline order for kernels

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_pairs(self) -> int:
        return int(self.pair_u.shape[0])


@dataclasses.dataclass(frozen=True)
class TemporalGraph:
    """Host-side temporal multigraph in canonical ArrayTEL layout.

    Immutable: :meth:`add_edges` returns a *new* graph with ``epoch`` + 1,
    so every epoch is a zero-copy-consistent snapshot — in-flight queries
    pinned to an older epoch keep peeling their snapshot's arrays while
    new arrivals land (the streaming service's snapshot-consistency
    contract rests on exactly this).
    """

    src: np.ndarray          # [E] int32, canonical order (pair_id, t)
    dst: np.ndarray          # [E] int32
    t: np.ndarray            # [E] int32
    pair_id: np.ndarray      # [E] int32 ascending
    pair_u: np.ndarray       # [P] int32
    pair_v: np.ndarray       # [P] int32
    num_vertices: int
    unique_ts: np.ndarray    # sorted unique timestamps
    epoch: int = 0           # bumped by every add_edges batch
    # process-unique identity (never reused, unlike id()); compare=False
    # keeps two structurally equal graphs equal
    uid: int = dataclasses.field(
        default_factory=lambda: next(_GRAPH_UID), compare=False)
    # lineage of the last append: the uid of the graph this one was grown
    # from and the [t_min, t_max] span of the appended batch — what lets
    # the core-result cache invalidate only entries the batch can affect
    parent_uid: Optional[int] = dataclasses.field(default=None, compare=False)
    appended_span: Optional[Tuple[int, int]] = dataclasses.field(
        default=None, compare=False)

    # ------------------------------------------------------------------ build
    @staticmethod
    def from_edges(u, v, t, num_vertices: Optional[int] = None, *,
                   strict: bool = False) -> "TemporalGraph":
        """Build from parallel arrays of (u, v, t) temporal edges.

        Self loops are dropped (they never contribute to distinct-neighbour
        degree).  Endpoints are normalized to u < v for pair identity — the
        graph is undirected, matching the paper's data model.

        Malformed batches raise :class:`GraphIngestError` instead of
        silently corrupting the TEL sort invariant: NaN/fractional values,
        negative or out-of-int32 vertex ids, ids >= an explicit
        ``num_vertices``, and timestamps outside int32 are always
        rejected; ``strict=True`` additionally rejects self-loops and
        negative timestamps.
        """
        u, v, t = _validate_edge_batch(u, v, t, strict=strict,
                                       num_vertices=num_vertices)
        keep = u != v
        u, v, t = u[keep], v[keep], t[keep]
        lo = np.minimum(u, v)
        hi = np.maximum(u, v)
        if num_vertices is None:
            num_vertices = int(hi.max()) + 1 if hi.size else 0
        # factorize pairs: sort by (lo, hi, t) then run-length encode
        order = np.lexsort((t, hi, lo))
        lo, hi, t = lo[order], hi[order], t[order]
        if lo.size:
            new_pair = np.empty(lo.shape, dtype=bool)
            new_pair[0] = True
            new_pair[1:] = (lo[1:] != lo[:-1]) | (hi[1:] != hi[:-1])
            pair_id = np.cumsum(new_pair) - 1
            pair_u = lo[new_pair]
            pair_v = hi[new_pair]
        else:
            pair_id = np.zeros(0, dtype=np.int64)
            pair_u = np.zeros(0, dtype=np.int64)
            pair_v = np.zeros(0, dtype=np.int64)
        return TemporalGraph(
            src=lo.astype(np.int32),
            dst=hi.astype(np.int32),
            t=t.astype(np.int32),
            pair_id=pair_id.astype(np.int32),
            pair_u=pair_u.astype(np.int32),
            pair_v=pair_v.astype(np.int32),
            num_vertices=int(num_vertices),
            unique_ts=np.unique(t).astype(np.int32),
        )

    @staticmethod
    def from_edge_list(edges, num_vertices: Optional[int] = None) -> "TemporalGraph":
        """Build from an iterable of (u, v, t) triples."""
        arr = np.asarray(list(edges), dtype=np.int64)
        if arr.size == 0:
            arr = arr.reshape(0, 3)
        return TemporalGraph.from_edges(arr[:, 0], arr[:, 1], arr[:, 2], num_vertices)

    # --------------------------------------------------------------- dynamic
    def add_edges(self, u, v, t, *, strict: bool = False) -> "TemporalGraph":
        """Dynamic-graph extension (paper §6.1): incremental merge-append.

        The paper appends one edge in O(1) by pointer surgery; the array
        equivalent is a *sorted-run merge*: the existing canonical arrays are
        already sorted by (pair_id, t), so a batch of B new edges only needs
        its own O(B log B) sort plus an O(E + B log E) two-run merge — never
        a full O(E log E) re-sort.  The result is bit-identical to a
        from-scratch :meth:`from_edges` rebuild (same canonical arrays, same
        pair factorization), with ``epoch`` bumped by one.  Timestamps may
        be arbitrary (late data is allowed — stricter than the paper, which
        assumes monotone arrival), and new vertices/pairs may appear.

        Malformed batches raise :class:`GraphIngestError` (see
        :meth:`from_edges`); ``strict=True`` additionally rejects
        self-loops and negative timestamps.
        """
        u, v, t = _validate_edge_batch(u, v, t, strict=strict)
        keep = u != v                       # self loops never contribute
        u, v, t = u[keep], v[keep], t[keep]
        if u.size == 0:
            return self
        lo = np.minimum(u, v)
        hi = np.maximum(u, v)
        n_vert = max(self.num_vertices, int(hi.max()) + 1)
        # canonicalize the batch: O(B log B), the only sort in the append
        order = np.lexsort((t, hi, lo))
        lo, hi, t = lo[order], hi[order], t[order]

        # --- merge the pair tables (64-bit (u, v) keys, both runs sorted)
        old_keys = (self.pair_u.astype(np.int64) << 32) | \
            self.pair_v.astype(np.int64)
        batch_keys = (lo << 32) | hi
        batch_pairs = np.unique(batch_keys)         # sorted-input unique: O(B)
        merged_keys = _merge_sorted_unique(old_keys, batch_pairs)
        # old pair id -> merged pair id is strictly increasing, so the old
        # edges stay sorted under the relabel
        old_pid_map = np.searchsorted(merged_keys, old_keys).astype(np.int64)
        pid_old = old_pid_map[self.pair_id.astype(np.int64)]
        pid_batch = np.searchsorted(merged_keys, batch_keys).astype(np.int64)

        # --- merge the edge runs on the composite (pair_id, t) key
        t_old = self.t.astype(np.int64)
        ckey_old = (pid_old << 32) | (t_old - _I32_MIN)
        ckey_batch = (pid_batch << 32) | (t - _I32_MIN)
        pos_b = np.searchsorted(ckey_old, ckey_batch, side="right") + \
            np.arange(ckey_batch.size)
        n_all = self.num_edges + lo.size
        is_new = np.zeros(n_all, dtype=bool)
        is_new[pos_b] = True

        def _interleave(old_col, new_col, dtype=np.int32):
            out = np.empty(n_all, dtype=dtype)
            out[pos_b] = new_col
            out[~is_new] = old_col
            return out

        return TemporalGraph(
            src=_interleave(self.src, lo),
            dst=_interleave(self.dst, hi),
            t=_interleave(self.t, t),
            pair_id=_interleave(pid_old, pid_batch),
            pair_u=(merged_keys >> 32).astype(np.int32),
            pair_v=(merged_keys & 0xFFFFFFFF).astype(np.int32),
            num_vertices=int(n_vert),
            unique_ts=_merge_sorted_unique(
                self.unique_ts, np.unique(t).astype(np.int32)),
            epoch=self.epoch + 1,
            parent_uid=self.uid,
            appended_span=(int(t.min()), int(t.max())),
        )

    # ----------------------------------------------------------------- views
    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def num_pairs(self) -> int:
        return int(self.pair_u.shape[0])

    @property
    def span(self):
        if self.t.size == 0:
            return (0, 0)
        return (int(self.t.min()), int(self.t.max()))

    def window_counts(self, ts: int, te: int):
        """(#edges, #unique timestamps) inside [ts, te] — host-side metadata."""
        m = (self.t >= ts) & (self.t <= te)
        return int(m.sum()), int(np.unique(self.t[m]).size)

    def tel_arrays(self, *, edge_capacity: Optional[int] = None,
                   pair_capacity: Optional[int] = None,
                   vertex_capacity: Optional[int] = None
                   ) -> Dict[str, np.ndarray]:
        """Host-side TEL arrays, optionally padded to capacity classes.

        Half-pair incidence is derived here (sorted by vertex) so the
        degree reduction also sees sorted segment ids.  With capacities,
        sentinel rows pad each array family: sentinel edges carry
        ``t = int32 min`` (outside every window) and ``pair_id`` equal to
        the padded pair count; sentinel half-pairs carry ``hp_src`` equal
        to ``vertex_capacity`` — out-of-range segment ids that the scatter
        reductions drop.  Compiled programs therefore depend only on the
        *capacity* shapes, not the live counts, which is what lets a
        streaming engine absorb appends without recompiling.
        """
        e, p = self.num_edges, self.num_pairs
        e_cap = e if edge_capacity is None else int(edge_capacity)
        p_cap = p if pair_capacity is None else int(pair_capacity)
        v_cap = (self.num_vertices if vertex_capacity is None
                 else int(vertex_capacity))
        if e_cap < e or p_cap < p or v_cap < self.num_vertices:
            raise ValueError("capacity below live count")

        def pad(a, n, fill, dtype=np.int32):
            if n == a.shape[0]:
                return a.astype(dtype, copy=False)
            out = np.full(n, fill, dtype=dtype)
            out[:a.shape[0]] = a
            return out

        hp_src = np.concatenate([self.pair_u, self.pair_v])
        hp_pair = np.concatenate(
            [np.arange(p, dtype=np.int32), np.arange(p, dtype=np.int32)])
        order = np.argsort(hp_src, kind="stable")
        t_pad = pad(self.t, e_cap, _I32_MIN)
        return {
            "src": pad(self.src, e_cap, 0),
            "dst": pad(self.dst, e_cap, 0),
            "t": t_pad,
            "pair_id": pad(self.pair_id, e_cap, p_cap),
            "pair_u": pad(self.pair_u, p_cap, 0),
            "pair_v": pad(self.pair_v, p_cap, 0),
            "hp_src": pad(hp_src[order].astype(np.int32), 2 * p_cap, v_cap),
            "hp_pair": pad(hp_pair[order].astype(np.int32), 2 * p_cap, 0),
            "time_perm": np.argsort(t_pad, kind="stable").astype(np.int32),
        }

    def device_tel(self, *, edge_capacity: Optional[int] = None,
                   pair_capacity: Optional[int] = None,
                   vertex_capacity: Optional[int] = None) -> DeviceTEL:
        """Ship to device, optionally padded to capacity classes (see
        :meth:`tel_arrays`).  Default (no capacities) is the exact TEL."""
        import jax.numpy as jnp

        arrs = self.tel_arrays(edge_capacity=edge_capacity,
                               pair_capacity=pair_capacity,
                               vertex_capacity=vertex_capacity)
        return DeviceTEL(**{k: jnp.asarray(v) for k, v in arrs.items()})

    def memory_bytes(self) -> int:
        """ArrayTEL footprint (paper Table 5 analogue)."""
        per_edge = 4 * 4 + 4  # src,dst,t,pair_id + time_perm
        per_pair = 4 * 2 + 4 * 2 * 2  # pair_u/v + half pairs (src,pair)x2
        return self.num_edges * per_edge + self.num_pairs * per_pair

    def fingerprint(self) -> int:
        """CRC32 over the canonical arrays + counts — a cheap structural
        identity for lineage-checked WAL replay.  Two graphs with equal
        fingerprints have byte-identical canonical TELs (same edges, same
        pair factorization, same epoch), so a replayed ``add_edges`` can
        be verified against the fingerprint its journal record promised.
        ``uid``/``parent_uid`` are process-local and deliberately
        excluded: lineage across restarts is exactly what the
        fingerprint replaces.
        """
        import zlib

        c = zlib.crc32(
            np.int64([self.num_vertices, self.epoch, self.num_edges,
                      self.num_pairs]).tobytes())
        for name in self._STATE_ARRAYS:
            a = np.ascontiguousarray(getattr(self, name))
            a = a.astype(a.dtype.newbyteorder("<"), copy=False)
            c = zlib.crc32(a.tobytes(), c)
        return c

    # ----------------------------------------------------------- persistence
    _STATE_ARRAYS = ("src", "dst", "t", "pair_id", "pair_u", "pair_v",
                     "unique_ts")

    def state_dict(self) -> Dict[str, np.ndarray]:
        """Serializable snapshot: the canonical arrays plus scalars as 0-d
        arrays — a flat str->ndarray mapping ``np.savez`` accepts directly.
        Round-trips exactly through :meth:`from_state` (the crash-recovery
        gate: a restored graph is bit-identical, epoch included)."""
        d = {name: np.asarray(getattr(self, name))
             for name in self._STATE_ARRAYS}
        d["num_vertices"] = np.int64(self.num_vertices)
        d["epoch"] = np.int64(self.epoch)
        return d

    @staticmethod
    def from_state(state) -> "TemporalGraph":
        """Inverse of :meth:`state_dict` (accepts an ``np.load`` mapping)."""
        return TemporalGraph(
            src=np.asarray(state["src"], np.int32),
            dst=np.asarray(state["dst"], np.int32),
            t=np.asarray(state["t"], np.int32),
            pair_id=np.asarray(state["pair_id"], np.int32),
            pair_u=np.asarray(state["pair_u"], np.int32),
            pair_v=np.asarray(state["pair_v"], np.int32),
            num_vertices=int(state["num_vertices"]),
            unique_ts=np.asarray(state["unique_ts"], np.int32),
            epoch=int(state["epoch"]),
        )
