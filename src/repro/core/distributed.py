"""Distributed TCQ engine: the paper's system at pod scale via shard_map.

Layout (mesh (pod, data, model) or (data, model)):
  * edges + pairs shard over `model`, split at PAIR boundaries so the
    edge->pair reduction never crosses shards (zero-collective pair stage);
    shards are padded to equal length with never-active sentinel edges.
  * query lanes (the OTCD wave) shard over `pod` x `data` — embarrassingly
    parallel, linear scaling.
  * the only cross-shard exchange is the per-iteration vertex-degree
    combine over `model`.  Two variants (EXPERIMENTS §Perf hillclimbs them):
      combine="psum":  all-reduce of the dense [V, Q_loc] f32 degrees;
      combine="rs_ag": psum_scatter the degrees, threshold locally, then
                       all-gather the 1-bit alive mask — ~36x less wire.

The paper's Table 5 notes billion-edge TELs "would require the distributed
memory cluster"; this module is that cluster design, with the tcq-billion
config lowering on the 512-chip multi-pod mesh.

Two generations of the sharded layout live here:

* :class:`ShardPlan` — the serving path.  Pair-to-shard ownership is
  *frozen* at build time as half-open ranges over the canonical 64-bit
  pair key ``(u << 32) | v`` (pair tables are key-sorted, so a range of
  keys is a range of pair ids on every snapshot).  Per-shard edge/pair
  buffers are power-of-two *capacity classes* with the same sentinel
  conventions as ``graph.tel_arrays`` (t = int32 min, local pair id =
  pair capacity, hp_src = vertex capacity), so a streaming append
  refreshes every shard **in place**: same shapes, same owners — no
  reshard, no recompile (``refresh`` only grows a capacity when the
  live count outruns it, amortized O(1) by doubling).

* :func:`build_wave_step` / :class:`DistributedTCQ` — the original
  scalar-threshold one-shot engine, kept for the collective-lowering
  dry runs (launch/dryrun.py) and as the minimal reference.

The serving hot path (``engine.WavePipeline`` subclassed as
:class:`ShardedWavePipeline`) runs :func:`make_sharded_step_fn`'s
per-lane-vector step: the same ``StepResult`` contract as
``core.wave.make_wave_step_fn`` — per-lane (ts, te, k, h), packed uint32
bitmask, TTI + edge counts — so the QueryState pool scheduler,
mid-flight admission, EmptyStaircase pruning and TTI-cache probes drive
sharded lanes unchanged, and every result is bit-identical to the
single-device engine (lanes are mathematically independent; a lane past
its fixpoint just rides idempotent extra iterations).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as PS

from repro.core.engine import WavePipeline, _Slot, unpack_alive_u32
from repro.core.graph import TemporalGraph, pow2_capacity
from repro.core.wave import (DegradationLadder, ResilienceConfig,
                             StepResult, _pack_u32, make_oracle_step_fn)
from repro.launch.mesh import dp_axes

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min


def mesh_shard_counts(mesh) -> Tuple[int, int]:
    """(lane_shards, model_shards) of a mesh: lanes shard over pod x data,
    edges over model."""
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = shape.get("model", 1)
    return mesh.devices.size // m, m


def _lane_axes(mesh):
    dp = dp_axes(mesh)
    return dp if len(dp) > 1 else dp[0]


# ===================================================================== plans
class ShardedTEL(NamedTuple):
    """Host-side pair-aligned edge partition, stacked as [m, ...] arrays."""
    src: np.ndarray        # [m, E_s]
    dst: np.ndarray        # [m, E_s]
    t: np.ndarray          # [m, E_s]  (int32 min => sentinel padding)
    pair_local: np.ndarray  # [m, E_s]  local pair id (P_s => sentinel)
    hp_src: np.ndarray     # [m, HP_s] vertex of half-pair (V_pad => sentinel)
    hp_pair: np.ndarray    # [m, HP_s] local pair id
    num_vertices: int      # padded to a multiple of 8*m
    num_pairs_shard: int
    num_shards: int


@dataclasses.dataclass(eq=False)
class ShardPlan:
    """Capacity-class sharded TEL with frozen pair-key ownership.

    ``bounds`` are m+1 half-open cuts over the canonical 64-bit pair key
    ``(pair_u << 32) | pair_v``: shard i owns every pair whose key falls
    in ``[bounds[i], bounds[i+1])``.  Pair tables are key-sorted on every
    snapshot (``TemporalGraph`` builds them that way), so ownership maps
    to contiguous pair-id ranges via one ``searchsorted`` — including for
    pairs that did not exist when the plan was built.  Edge/pair buffers
    are pow2 capacity classes with ``tel_arrays``-compatible sentinels,
    so :meth:`refresh` absorbs appends without changing shapes (the
    compiled sharded step's jit cache stays warm across epochs).

    Duck-types :class:`ShardedTEL`'s fields, so the legacy one-shot
    engine (`build_wave_step`, `DistributedTCQ`) runs on it unchanged.
    """

    src: np.ndarray          # [m, e_cap]
    dst: np.ndarray          # [m, e_cap]
    t: np.ndarray            # [m, e_cap]   (int32 min => sentinel)
    pair_local: np.ndarray   # [m, e_cap]   (p_cap => sentinel)
    hp_src: np.ndarray       # [m, 2*p_cap] (v_pad => sentinel)
    hp_pair: np.ndarray      # [m, 2*p_cap]
    num_vertices: int        # v_pad: multiple of 8*m
    num_pairs_shard: int     # p_cap
    num_shards: int          # m
    bounds: np.ndarray       # [m+1] int64 frozen pair-key cuts
    epoch: int = 0

    @property
    def e_cap(self) -> int:
        return int(self.src.shape[1])

    @property
    def p_cap(self) -> int:
        return int(self.num_pairs_shard)

    # ------------------------------------------------------------- building
    @staticmethod
    def _pair_keys(graph: TemporalGraph) -> np.ndarray:
        return ((graph.pair_u.astype(np.int64) << 32)
                | graph.pair_v.astype(np.int64))

    @staticmethod
    def _cuts(graph: TemporalGraph, bounds: np.ndarray
              ) -> Tuple[np.ndarray, np.ndarray]:
        """(pair cuts [m+1], edge cuts [m+1]) of a snapshot under frozen
        key bounds.  Edges are (pair, t)-sorted, so each shard's edges
        are one contiguous slice."""
        keys = ShardPlan._pair_keys(graph)
        pcuts = np.searchsorted(keys, bounds).astype(np.int64)
        ecuts = np.searchsorted(graph.pair_id, pcuts).astype(np.int64)
        return pcuts, ecuts

    @classmethod
    def build(cls, graph: TemporalGraph, m: int, *,
              vertex_capacity: Optional[int] = None) -> "ShardPlan":
        """Freeze edge-balanced pair-aligned ownership over ``graph``."""
        e, p = graph.num_edges, graph.num_pairs
        keys = cls._pair_keys(graph)
        # edge-balanced cuts, frozen as the KEY of the pair at each cut
        # so ownership survives pair renumbering across appends
        bounds = np.empty(m + 1, np.int64)
        bounds[0] = np.iinfo(np.int64).min
        bounds[m] = np.iinfo(np.int64).max
        for i in range(1, m):
            target = min(i * (-(-e // m)), e)
            if e == 0 or target >= e:
                bounds[i] = bounds[m]
                continue
            pid = int(graph.pair_id[min(target, e - 1)])
            bounds[i] = keys[pid]
        v_pad = cls._round_vertices(
            graph.num_vertices if vertex_capacity is None
            else vertex_capacity, m)
        plan = cls(src=None, dst=None, t=None, pair_local=None, hp_src=None,
                   hp_pair=None, num_vertices=v_pad, num_pairs_shard=0,
                   num_shards=m, bounds=bounds, epoch=int(graph.epoch))
        plan._refill(graph, grow_only=False)
        return plan

    @staticmethod
    def _round_vertices(v: int, m: int) -> int:
        # byte-aligned per model shard: the rs_ag alive exchange slices V/m
        # columns and the packed transfer works in whole bytes
        return -(-max(1, int(v)) // (8 * m)) * 8 * m

    def refresh(self, graph: TemporalGraph, *,
                vertex_capacity: Optional[int] = None) -> bool:
        """Re-fill every shard from a new snapshot under the frozen
        ownership bounds.  Returns True when no buffer changed shape —
        the streaming steady state: the sharded step's compiled program
        is reused as-is.  A capacity that overflows grows to the next
        power of two (new shapes, one recompile — amortized O(1))."""
        if vertex_capacity is not None:
            v_pad = self._round_vertices(vertex_capacity, self.num_shards)
            if v_pad < self.num_vertices:
                v_pad = self.num_vertices    # vertex width never shrinks
        else:
            v_pad = max(self.num_vertices,
                        self._round_vertices(graph.num_vertices,
                                             self.num_shards))
        same_v = v_pad == self.num_vertices
        self.num_vertices = v_pad
        same = self._refill(graph, grow_only=True) and same_v
        self.epoch = int(graph.epoch)
        return same

    def _refill(self, graph: TemporalGraph, *, grow_only: bool) -> bool:
        m = self.num_shards
        pcuts, ecuts = self._cuts(graph, self.bounds)
        n_e = int((ecuts[1:] - ecuts[:-1]).max()) if m else 0
        n_p = int((pcuts[1:] - pcuts[:-1]).max()) if m else 0
        e_cap = pow2_capacity(n_e)
        p_cap = pow2_capacity(n_p)
        if grow_only:
            same = e_cap <= self.e_cap and p_cap <= self.p_cap
            e_cap = max(e_cap, self.e_cap)
            p_cap = max(p_cap, self.p_cap)
        else:
            same = False
        v_pad = self.num_vertices
        src = np.zeros((m, e_cap), np.int32)
        dst = np.zeros((m, e_cap), np.int32)
        tt = np.full((m, e_cap), _I32_MIN, np.int32)
        pl = np.full((m, e_cap), p_cap, np.int32)
        hps = np.full((m, 2 * p_cap), v_pad, np.int32)
        hpp = np.zeros((m, 2 * p_cap), np.int32)
        for i in range(m):
            a, b = int(ecuts[i]), int(ecuts[i + 1])
            lo, hi = int(pcuts[i]), int(pcuts[i + 1])
            n = b - a
            src[i, :n] = graph.src[a:b]
            dst[i, :n] = graph.dst[a:b]
            tt[i, :n] = graph.t[a:b]
            pl[i, :n] = graph.pair_id[a:b] - lo
            np_l = hi - lo
            h_src = np.concatenate([graph.pair_u[lo:hi],
                                    graph.pair_v[lo:hi]])
            h_pair = np.concatenate([np.arange(np_l), np.arange(np_l)])
            order = np.argsort(h_src, kind="stable")
            hps[i, :2 * np_l] = h_src[order]
            hpp[i, :2 * np_l] = h_pair[order]
        self.src, self.dst, self.t, self.pair_local = src, dst, tt, pl
        self.hp_src, self.hp_pair = hps, hpp
        self.num_pairs_shard = p_cap
        return same

    def window_arrays(self, graph: TemporalGraph, ts: int, te: int
                      ) -> Tuple[np.ndarray, ...]:
        """Window-truncated per-shard edge arrays (src, dst, t,
        pair_local), pow2-bucketed like ``TCQEngine._window_tel``'s
        single-device truncation so compiled step programs are shared
        across windows of similar size.  ``graph`` may be any snapshot
        whose pairs the frozen bounds cover (ancestors always qualify);
        the half-pair tables come from :meth:`hp_arrays`."""
        m = self.num_shards
        pcuts, ecuts = self._cuts(graph, self.bounds)
        win = (graph.t >= ts) & (graph.t <= te)
        locs = []
        for i in range(m):
            a, b = int(ecuts[i]), int(ecuts[i + 1])
            locs.append(np.flatnonzero(win[a:b]) + a)
        e_cap = pow2_capacity(max((loc.size for loc in locs), default=0))
        src = np.zeros((m, e_cap), np.int32)
        dst = np.zeros((m, e_cap), np.int32)
        tt = np.full((m, e_cap), _I32_MIN, np.int32)
        pl = np.full((m, e_cap), self.p_cap, np.int32)
        for i, loc in enumerate(locs):
            n = loc.size
            src[i, :n] = graph.src[loc]
            dst[i, :n] = graph.dst[loc]
            tt[i, :n] = graph.t[loc]
            pl[i, :n] = graph.pair_id[loc] - int(pcuts[i])
        return src, dst, tt, pl

    def hp_arrays(self, graph: TemporalGraph) -> Tuple[np.ndarray, ...]:
        """Half-pair tables (hp_src, hp_pair) for any covered snapshot at
        the plan's current capacities.  For the plan's own snapshot these
        are just ``(self.hp_src, self.hp_pair)``."""
        if int(graph.epoch) == self.epoch:
            return self.hp_src, self.hp_pair
        m = self.num_shards
        pcuts, _ = self._cuts(graph, self.bounds)
        n_p = int((pcuts[1:] - pcuts[:-1]).max()) if m else 0
        if n_p > self.p_cap:
            raise ValueError("snapshot exceeds plan pair capacity — not "
                             "an ancestor of the plan's current graph")
        hps = np.full((m, 2 * self.p_cap), self.num_vertices, np.int32)
        hpp = np.zeros((m, 2 * self.p_cap), np.int32)
        for i in range(m):
            lo, hi = int(pcuts[i]), int(pcuts[i + 1])
            np_l = hi - lo
            h_src = np.concatenate([graph.pair_u[lo:hi],
                                    graph.pair_v[lo:hi]])
            h_pair = np.concatenate([np.arange(np_l), np.arange(np_l)])
            order = np.argsort(h_src, kind="stable")
            hps[i, :2 * np_l] = h_src[order]
            hpp[i, :2 * np_l] = h_pair[order]
        return hps, hpp


def shard_graph(graph: TemporalGraph, m: int) -> ShardPlan:
    """Pair-aligned edge partition over ``m`` model shards.

    Returns a capacity-class :class:`ShardPlan` (pow2 sentinel-padded,
    ``refresh``-able in place across appends); duck-types the legacy
    :class:`ShardedTEL` fields.
    """
    return ShardPlan.build(graph, m)


def abstract_sharded_tel(num_vertices: int, num_edges: int, num_pairs: int,
                         m: int) -> ShardedTEL:
    """ShapeDtypeStruct stand-in for the dry-run (no allocation)."""
    e_s = -(-num_edges // m)
    p_s = -(-num_pairs // m)
    v_pad = -(-num_vertices // (8 * m)) * 8 * m
    sds = lambda shape: jax.ShapeDtypeStruct(shape, jnp.int32)  # noqa: E731
    tel = ShardedTEL(sds((m, e_s)), sds((m, e_s)), sds((m, e_s)),
                     sds((m, e_s)), sds((m, 2 * p_s)), sds((m, 2 * p_s)),
                     v_pad, p_s, m)
    return tel


# ======================================================= degree primitives
def _local_degrees(src, dst, t, pair_l, hp_src, hp_pair, alive, ts, te, h,
                   *, p_s, v_pad):
    """One shard's partial degrees.  alive: [Qloc, V]; returns [V, Qloc]."""
    win = (t[None, :] >= ts[:, None]) & (t[None, :] <= te[:, None])
    ea = win & alive[:, src] & alive[:, dst]                 # [Qloc, E_s]
    paircnt = jax.ops.segment_sum(ea.T.astype(jnp.float32), pair_l,
                                  num_segments=p_s + 1,
                                  indices_are_sorted=True)[:p_s]
    pairact = (paircnt >= h).astype(jnp.float32)             # [P_s, Qloc]
    contrib = pairact[jnp.minimum(hp_pair, p_s - 1), :]
    deg = jax.ops.segment_sum(contrib, hp_src,
                              num_segments=v_pad + 1,
                              indices_are_sorted=True)[:v_pad]
    return deg                                               # [V, Qloc]


def build_wave_step(mesh, *, num_vertices: int, combine: str = "rs_ag",
                    p_s: int, max_iters: int = 0, single_iteration=False):
    """shard_map'd batched peel over (pod, data | data) query lanes and
    model-axis edge shards.  Returns a jit-able
    step(tel_arrays..., alive, ts, te, k, h) -> (alive, tti_lo, tti_hi,
    n_edges, iters)."""
    dp = dp_axes(mesh)
    m = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    v_pad = num_vertices
    assert v_pad % m == 0

    def one_iter(src, dst, t, pair_l, hp_src, hp_pair, alive, ts, te, k, h):
        deg_part = _local_degrees(src, dst, t, pair_l, hp_src, hp_pair,
                                  alive, ts, te, h, p_s=p_s, v_pad=v_pad)
        if combine == "psum":
            deg = lax.psum(deg_part, "model").T              # [Qloc, V]
            return alive & (deg >= k)
        deg_s = lax.psum_scatter(deg_part, "model",
                                 scatter_dimension=0, tiled=True).T
        idx = lax.axis_index("model")
        v_m = v_pad // m
        alive_slice = lax.dynamic_slice_in_dim(alive, idx * v_m, v_m, axis=1)
        new_slice = alive_slice & (deg_s >= k)
        if combine == "rs_ag_packed":
            # §Perf iteration 3: gather 1 BIT per vertex instead of one
            # byte — 8x less wire on the alive exchange
            packed = jnp.packbits(new_slice, axis=1)
            gathered = lax.all_gather(packed, "model", axis=1, tiled=True)
            return jnp.unpackbits(
                gathered, axis=1, count=v_pad).astype(bool)
        return lax.all_gather(new_slice, "model", axis=1, tiled=True)

    def step(src, dst, t, pair_l, hp_src, hp_pair, alive, ts, te, k, h):
        src, dst, t = src[0], dst[0], t[0]
        pair_l, hp_src, hp_pair = pair_l[0], hp_src[0], hp_pair[0]
        if single_iteration:
            alive = one_iter(src, dst, t, pair_l, hp_src, hp_pair, alive,
                             ts, te, k, h)
            iters = jnp.int32(1)
        else:
            def cond(s):
                a, changed, it = s
                more = changed
                if max_iters:
                    more = more & (it < max_iters)
                return more

            def body(s):
                a, _, it = s
                na = one_iter(src, dst, t, pair_l, hp_src, hp_pair, a,
                              ts, te, k, h)
                return na, jnp.any(na != a), it + 1

            alive, _, iters = lax.while_loop(
                cond, body, (alive, jnp.bool_(True), jnp.int32(0)))
        # TTI + edge counts: local then min/max/sum over the model axis
        win = (t[None, :] >= ts[:, None]) & (t[None, :] <= te[:, None])
        ea = win & alive[:, src] & alive[:, dst]
        n_edges = lax.psum(jnp.sum(ea, axis=1, dtype=jnp.int32), "model")
        lo = lax.pmin(jnp.min(jnp.where(ea, t[None, :], _I32_MAX), axis=1),
                      "model")
        hi = lax.pmax(jnp.max(jnp.where(ea, t[None, :], _I32_MIN),
                              axis=1), "model")
        return alive, lo, hi, n_edges, iters

    edge_spec = PS("model", None)
    lane_axes = dp if len(dp) > 1 else dp[0]
    lane = PS(lane_axes)
    alive_spec = PS(lane_axes, None)
    from jax.experimental.shard_map import shard_map

    smapped = shard_map(
        step, mesh=mesh,
        in_specs=(edge_spec, edge_spec, edge_spec, edge_spec, edge_spec,
                  edge_spec, alive_spec, lane, lane, PS(), PS()),
        out_specs=(alive_spec, lane, lane, lane, PS()),
        check_rep=False)
    return smapped


def wave_shardings(mesh, num_vertices: int, m: int):
    dp = dp_axes(mesh)
    lane = dp if len(dp) > 1 else dp[0]
    return {
        "edges": NamedSharding(mesh, PS("model", None)),
        "alive": NamedSharding(mesh, PS(lane, None)),
        "lane": NamedSharding(mesh, PS(lane)),
        "scalar": NamedSharding(mesh, PS()),
    }


# ============================================== serving step (per-lane k/h)
def combine_bytes_per_lane_iter(combine: str, num_vertices: int,
                                model_shards: int) -> int:
    """Analytic wire bytes one lane moves through the degree combine per
    fixpoint iteration (ring-collective model, summed across the mesh).

    psum:  all-reduce of [V] f32 partial degrees — 2*(m-1)/m * 4V bytes
           per shard, m shards.
    rs_ag: psum_scatter the same payload one direction ((m-1)/m * 4V per
           shard) plus an all-gather of the V/m-slice bool alive mask
           ((m-1)/m * V bytes per shard).
    """
    m = int(model_shards)
    if m <= 1:
        return 0
    v = int(num_vertices)
    if combine == "psum":
        return 2 * (m - 1) * 4 * v
    return (m - 1) * (4 * v + v)


def _all_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


@functools.lru_cache(maxsize=32)
def _sharded_step_jit(mesh, v_pad: int, p_cap: int, combine: str,
                      donate: bool):
    """jit(shard_map) for the per-lane-vector sharded step.  Cached per
    (mesh, capacities, combine): jit itself re-specializes per edge-cap
    bucket, so one entry serves every window in a capacity class."""
    from jax.experimental.shard_map import shard_map

    L, m = mesh_shard_counts(mesh)
    assert v_pad % max(1, m) == 0
    axes = _all_axes(mesh)
    lane_axes = _lane_axes(mesh)
    edge_spec = PS("model", None)
    lane = PS(lane_axes)
    alive_spec = PS(lane_axes, None)

    def local_step(src, dst, t, pair_l, hp_src, hp_pair, alive,
                   ts, te, k, h):
        src, dst, t, pair_l = src[0], dst[0], t[0], pair_l[0]
        hp_src, hp_pair = hp_src[0], hp_pair[0]
        # the [Wloc, E_s] window mask depends only on (ts, te) — hoisted
        # out of the fixpoint loop exactly like peel_to_fixpoint
        win = (t[None, :] >= ts[:, None]) & (t[None, :] <= te[:, None])

        def cond(s):
            return s[2]

        def body(s):
            cur, _, _, it = s
            ea = win & cur[:, src] & cur[:, dst]
            paircnt = jax.ops.segment_sum(
                ea.T.astype(jnp.float32), pair_l,
                num_segments=p_cap + 1, indices_are_sorted=True)[:p_cap]
            pairact = (paircnt >= h[None, :]).astype(jnp.float32)
            contrib = pairact[hp_pair, :]
            deg_part = jax.ops.segment_sum(
                contrib, hp_src,
                num_segments=v_pad + 1, indices_are_sorted=True)[:v_pad]
            if m == 1 or combine == "psum":
                deg = deg_part if m == 1 else lax.psum(deg_part, "model")
                new = cur & (deg.T >= k[:, None])
            else:
                deg_s = lax.psum_scatter(deg_part, "model",
                                         scatter_dimension=0, tiled=True).T
                idx = lax.axis_index("model")
                v_m = v_pad // m
                a_slice = lax.dynamic_slice_in_dim(cur, idx * v_m, v_m,
                                                   axis=1)
                new_slice = a_slice & (deg_s >= k[:, None])
                new = lax.all_gather(new_slice, "model", axis=1, tiled=True)
            return new, ea, jnp.any(new != cur), it + 1

        ea0 = jnp.zeros((alive.shape[0], t.shape[0]), dtype=bool)
        alive, ea, _, iters = lax.while_loop(
            cond, body, (alive, ea0, jnp.bool_(True), jnp.int32(0)))
        # the final iteration observed new == cur, so the carried ea is
        # the fixpoint's edge activity — local stats then mesh reductions
        n_edges = jnp.sum(ea, axis=1, dtype=jnp.int32)
        lo = jnp.min(jnp.where(ea, t[None, :], _I32_MAX), axis=1)
        hi = jnp.max(jnp.where(ea, t[None, :], _I32_MIN), axis=1)
        if m > 1:
            n_edges = lax.psum(n_edges, "model")
            lo = lax.pmin(lo, "model")
            hi = lax.pmax(hi, "model")
        iters = lax.pmax(iters, axes)
        return StepResult(alive, _pack_u32(alive, v_pad), lo, hi,
                          n_edges, iters)

    smapped = shard_map(
        local_step, mesh=mesh,
        in_specs=(edge_spec, edge_spec, edge_spec, edge_spec, edge_spec,
                  edge_spec, alive_spec, lane, lane, lane, lane),
        out_specs=StepResult(alive_spec, PS(lane_axes, None), lane, lane,
                             lane, PS()),
        check_rep=False)
    return jax.jit(smapped, donate_argnums=(6,) if donate else ())


def make_sharded_step_fn(mesh, arrays, *, num_vertices: int, p_cap: int,
                         combine: str = "psum", donate: bool = True):
    """Per-lane-vector sharded device step with the single-device
    ``StepResult`` contract: ``step(alive, ts, te, k, h)``, ts/te/k/h
    per-lane [W] vectors, alive [W, V] sharded over the lane axis.

    ``arrays`` are the six device edge/pair shards (src, dst, t,
    pair_local, hp_src, hp_pair), each [m, ...] with PS("model", None)
    placement.  The alive buffer is donated through the step when
    ``donate`` (the pipeline's persistent lane slab); ladder rungs pass
    ``donate=False`` so failed calls replay intact.
    """
    L, m = mesh_shard_counts(mesh)
    jitted = _sharded_step_jit(mesh, int(num_vertices), int(p_cap),
                               combine, bool(donate))
    lane_sh = NamedSharding(mesh, PS(_lane_axes(mesh)))

    def step(alive, ts, te, k, h):
        w = alive.shape[0]
        lanes = [x if (isinstance(x, jax.Array) and x.shape == (w,)
                       and x.sharding == lane_sh)
                 else jax.device_put(
                     jnp.broadcast_to(jnp.asarray(x, jnp.int32), (w,)),
                     lane_sh)
                 for x in (ts, te, k, h)]
        return jitted(*arrays, alive, *lanes)

    step.backend = "xla_sharded"
    step.interpret = False
    step.combine = combine
    step.lane_shards = L
    step.model_shards = m
    step.bytes_per_lane_iter = combine_bytes_per_lane_iter(
        combine, num_vertices, m)
    return step


def make_sharded_kernel_step(mesh, tel, num_vertices: int, *,
                             w_tile: int = 8,
                             interpret: Optional[bool] = None,
                             vmem_budget_bytes: Optional[int] = None):
    """Fused Pallas peel-to-fixpoint kernel as the per-shard local step.

    Only meshes with a trivial model axis qualify (model=1 — edges
    replicated, lanes sharded over pod x data): the kernel's host-side
    band analysis bakes one TEL's segment structure into the program,
    and shard_map is SPMD — m model shards would need m different
    programs.  On model-sharded meshes callers fall back to the XLA
    composite local step (the ladder logs the unavailable rung).

    Returns None when the kernel itself declines (VMEM budget).
    """
    L, m = mesh_shard_counts(mesh)
    if m != 1:
        return None
    from jax.experimental.shard_map import shard_map
    from repro.kernels.wave_peel.ops import (DEFAULT_VMEM_BUDGET,
                                             make_fused_wave_step)

    budget = (DEFAULT_VMEM_BUDGET if vmem_budget_bytes is None
              else int(vmem_budget_bytes))
    fused = make_fused_wave_step(tel, num_vertices, w_tile=w_tile,
                                 interpret=interpret, donate=False,
                                 vmem_budget_bytes=budget)
    if fused is None:
        return None
    axes = _all_axes(mesh)
    lane_axes = _lane_axes(mesh)
    lane = PS(lane_axes)
    alive_spec = PS(lane_axes, None)
    lane_sh = NamedSharding(mesh, lane)

    def local_step(alive, ts, te, k, h):
        res = fused(alive, ts, te, k, h)     # inlines: kernel per shard
        return res._replace(iters=lax.pmax(res.iters, axes))

    smapped = shard_map(
        local_step, mesh=mesh,
        in_specs=(alive_spec, lane, lane, lane, lane),
        out_specs=StepResult(alive_spec, PS(lane_axes, None), lane, lane,
                             lane, PS()),
        check_rep=False)
    jitted = jax.jit(smapped)

    def step(alive, ts, te, k, h):
        w = alive.shape[0]
        lanes = [jax.device_put(
            jnp.broadcast_to(jnp.asarray(x, jnp.int32), (w,)), lane_sh)
            for x in (ts, te, k, h)]
        return jitted(alive, *lanes)

    step.backend = "pallas"
    step.interpret = bool(getattr(fused, "interpret", False))
    step.combine = "none"
    step.lane_shards = L
    step.model_shards = m
    step.bytes_per_lane_iter = 0
    return step


class ShardedDegradationLadder(DegradationLadder):
    """PR 5's graceful-degradation ladder over the *sharded* lowerings:
    fused Pallas within-shard (lane-sharded meshes) -> sharded XLA
    composite -> serial numpy oracle.

    shard_map programs are SPMD, so demotion swaps the local step for
    every shard at once (per-shard host control flow cannot live inside
    one program); the kernel rung *is* the per-shard local step when the
    mesh qualifies (model=1).  Inherits the call/tripwire/demote
    machinery from :class:`core.wave.DegradationLadder` — the tripwire
    recomputes one random lane on the unsharded numpy oracle, which the
    sharded step must match bit-for-bit (lanes are independent), and a
    demoted-to-oracle pool keeps running: the pipeline's refill jits
    re-pin the unsharded oracle output to the mesh on the next assemble.
    """

    def __init__(self, mesh, arrays, tel, num_vertices: int, *,
                 p_cap: int, combine: str = "psum",
                 use_kernel: bool = False, w_tile: int = 8,
                 config: Optional[ResilienceConfig] = None):
        # rebuild DegradationLadder.__init__'s state by hand: the rungs
        # here are sharded lowerings, not the single-device ones
        self.config = config or ResilienceConfig()
        self.events = []
        self.calls = 0
        self.rung = 0
        self._rng = np.random.default_rng(self.config.seed)
        L, m = mesh_shard_counts(mesh)
        interpret = self.config.interpret
        rungs = []
        if use_kernel:
            if m != 1:
                self._log("pallas", "multi_shard",
                          f"model={m}: the fused kernel bakes one TEL's "
                          "band structure; kernel-within-shard needs a "
                          "lane-only mesh")
            else:
                try:
                    fused = make_sharded_kernel_step(
                        mesh, tel, num_vertices, w_tile=w_tile,
                        interpret=interpret,
                        vmem_budget_bytes=self.config.vmem_budget_bytes)
                    if fused is None:
                        self._log("pallas", "vmem_budget", "")
                    else:
                        rungs.append(("pallas", fused))
                except Exception as e:               # pragma: no cover
                    self._log("pallas", "build_error", repr(e))
        rungs.append(("xla", make_sharded_step_fn(
            mesh, arrays, num_vertices=num_vertices, p_cap=p_cap,
            combine=combine, donate=False)))
        oracle = make_oracle_step_fn(tel, num_vertices)
        self._truth = oracle
        rungs.append(("oracle", oracle))
        wrap = self.config.rung_wrapper
        if wrap is not None:
            rungs = [(name, wrap(name, fn) or fn) for name, fn in rungs]
        self.rungs = rungs
        self.combine = combine
        self.lane_shards = L
        self.model_shards = m
        self.bytes_per_lane_iter = combine_bytes_per_lane_iter(
            combine, num_vertices, m)


# ================================================== sharded lane pipeline
@functools.lru_cache(maxsize=64)
def _sharded_lane_fns(ash: NamedSharding):
    """Batched lane-refill jits pinned to one alive sharding.

    At W = 64-512 sharded lanes, per-lane refill dispatch (one jit call
    per lane, ~0.1 ms each) would dominate the step itself; instead every
    assemble issues at most two device calls: one codes-vector constant
    fill (0=keep, 1=ones, 2=zeros) and one row-scatter for the warm
    starts.  Both donate the buffer and pin the sharded layout.
    """
    fill = jax.jit(
        lambda buf, codes: jnp.where((codes == 0)[:, None], buf,
                                     (codes == 1)[:, None]),
        donate_argnums=(0,), out_shardings=ash)
    scatter = jax.jit(
        lambda buf, idx, rows: buf.at[idx].set(rows),
        donate_argnums=(0,), out_shardings=ash)
    return fill, scatter


class ShardedWavePipeline(WavePipeline):
    """Mesh-spanning depth-D slot ring: ``engine.WavePipeline`` whose
    lane buffers live sharded over the mesh's lane axis and whose device
    step is the shard_map'd peel.

    The pool scheduler — EDF claiming, mid-flight admission, staircase
    pruning, TTI-cache probes — runs unchanged on host (it only ever
    touches lanes through the step's StepResult and the refill hooks);
    what changes is the device side:

    * slot buffers are allocated sharded ([W, V] with lanes split over
      pod x data) and stay sharded through every donated step;
    * lane refills are *batched*: one constant-fill call + one warm-row
      scatter per assemble instead of up to W per-lane dispatches — at
      W = 64-512 sharded lanes the per-call dispatch overhead would
      otherwise swallow the step-amortization win (the single-device
      pipeline keeps its historical per-lane refills);
    * per-shard occupancy and combine-collective wire bytes are
      accounted per pool and surfaced through ``QueryStats`` /
      ``TCQEngine.stats()["distributed"]``.
    """

    def __init__(self, step_fn, *, mesh, num_vertices: int, wave: int,
                 depth: int = 2, dist_counters: Optional[dict] = None):
        L, m = mesh_shard_counts(mesh)
        if wave % L:
            raise ValueError(
                f"wave={wave} not a multiple of lane shards {L}")
        super().__init__(None, num_vertices, None, None, wave, depth,
                         step_fn=step_fn)
        self.mesh = mesh
        self.lane_shards = L
        self.model_shards = m
        self._w_loc = wave // L
        self._ash = NamedSharding(mesh, PS(_lane_axes(mesh), None))
        self._lsh = NamedSharding(mesh, PS(_lane_axes(mesh)))
        self._fill_codes, self._scatter = _sharded_lane_fns(self._ash)
        self._bytes_per_lane_iter = int(
            getattr(step_fn, "bytes_per_lane_iter", 0))
        self._shard_occupied = [0] * L
        self._dist = dist_counters

    # ----------------------------------------------------------- hooks
    def _new_slot(self) -> _Slot:
        buf = jax.device_put(
            np.zeros((self.wave, self.num_vertices), dtype=bool),
            self._ash)
        return _Slot(self.wave, self.num_vertices, buf=buf)

    def _refill_lanes(self, buf, sets, fills):
        if fills:
            codes = np.zeros(self.wave, np.int32)
            for li, value in fills:
                codes[li] = 1 if value else 2
            buf = self._fill_codes(buf, codes)
        if sets:
            # pow2-bucketed scatter width: pad by repeating the first
            # (index, row) pair — duplicate scatters of identical rows
            # commute — so R in [1, W] warm rows costs log2(W) compiled
            # variants instead of W.  Rows are stacked host-side (warm
            # rows arrive as host bitmask unpacks) so the whole batch
            # commits in the one scatter dispatch instead of per-row.
            r = pow2_capacity(len(sets), floor=1)
            idx = np.empty(r, np.int32)
            rows = np.empty((r, buf.shape[1]), bool)
            for j in range(r):
                li, row = sets[min(j, len(sets) - 1)]
                idx[j] = li
                rows[j] = np.asarray(row, dtype=bool)
            buf = self._scatter(buf, idx, rows)
        return buf

    def _record_occupied(self, occupied) -> None:
        for li in occupied:
            self._shard_occupied[li // self._w_loc] += 1

    def _warm_row(self, res, packed, li):
        """Host-unpack the lane's already-fetched u32 bitmask: slicing
        the mesh-sharded ``res.alive`` would be an eager 8-device gather
        per promoted row (the dominant retire cost at W >= 256)."""
        v = self.num_vertices
        return lambda: unpack_alive_u32(packed[li], v)

    def _commit_params(self, slot, params):
        """Lane params only change when lanes are refilled; committing
        the (ts, te, k, h) vectors across L shards every step would cost
        4L host->device transfers per step.  Cache the committed arrays
        on the slot and re-place them only when the host vectors moved."""
        cached = getattr(slot, "_params_np", None)
        if cached is not None and all(
                np.array_equal(a, b) for a, b in zip(cached, params)):
            return slot._params_dev
        slot._params_np = tuple(p.copy() for p in params)
        slot._params_dev = tuple(
            jax.device_put(list(params), [self._lsh] * len(params)))
        return slot._params_dev

    def _finish_pool(self, pool_stats) -> None:
        steps = pool_stats.device_steps
        if steps:
            pool_stats.shard_occupancy = [
                c / (steps * self._w_loc) for c in self._shard_occupied]
        pool_stats.collective_bytes = (
            self._bytes_per_lane_iter * self.wave * pool_stats.peel_iters)
        self._shard_occupied = [0] * self.lane_shards
        if self._dist is not None:
            self._dist["pool_runs"] += 1
            self._dist["device_steps"] += steps
            self._dist["collective_bytes"] += pool_stats.collective_bytes


# =============================================== one-shot reference engine
class DistributedTCQ:
    """Runnable distributed engine (any mesh, incl. degenerate test meshes).

    On a single-device mesh the shard_map program degenerates to the
    plain composite with collective no-ops, so the single-shard block
    routes through ``core.wave.make_wave_step_fn`` instead — the fused
    Pallas peel-to-fixpoint kernel on TPU, the XLA composite elsewhere
    (``use_fused=False`` restores the pure shard_map path, e.g. for the
    collective-lowering dry runs; ``True`` forces the kernel).  Multi-
    device meshes always run the sharded step — the fused kernel owns
    the *intra-shard* work and the model-axis degree combine stays a
    collective.
    """

    def __init__(self, graph: TemporalGraph, mesh, combine: str = "rs_ag",
                 *, use_fused: Optional[bool] = None):
        self.graph = graph
        self.mesh = mesh
        m = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        plan = shard_graph(graph, m)
        self.plan = plan
        sh = wave_shardings(mesh, plan.num_vertices, m)
        self.arrays = tuple(
            jax.device_put(a, sh["edges"])
            for a in (plan.src, plan.dst, plan.t, plan.pair_local,
                      plan.hp_src, plan.hp_pair))
        self.step = jax.jit(build_wave_step(
            mesh, num_vertices=plan.num_vertices, combine=combine,
            p_s=plan.num_pairs_shard))
        self._sh = sh
        self._fused = None
        if mesh.devices.size == 1 and use_fused is not False:
            from repro.core.wave import make_wave_step_fn

            tel = graph.device_tel(vertex_capacity=plan.num_vertices)
            self._fused = make_wave_step_fn(tel, plan.num_vertices,
                                            use_kernel=use_fused)

    def query_wave(self, ts, te, k: int, h: int = 1, alive=None, *,
                   packed: bool = False):
        """Batched peel over the sharded TEL.  With ``packed=True`` the
        alive masks come back as [Q, ceil(V/32)] uint32 bitmasks (the
        engine's packed result-transfer path — 8x less wire than bool
        masks when the caller only needs them host-side; decode with
        ``engine.unpack_alive_u32``)."""
        q = len(ts)
        v = self.plan.num_vertices
        if alive is None:
            alive = jnp.ones((q, v), dtype=bool)
        if self._fused is not None:
            # single-shard block: the fused step already emits the packed
            # bitmask, so the packed transfer costs nothing extra here
            r = self._fused(jnp.asarray(alive, dtype=bool),
                            jnp.asarray(ts, jnp.int32),
                            jnp.asarray(te, jnp.int32),
                            jnp.int32(k), jnp.int32(h))
            if packed:
                return r.packed, r.tti_lo, r.tti_hi, r.n_edges, r.iters
            return r.alive, r.tti_lo, r.tti_hi, r.n_edges, r.iters
        alive = jax.device_put(alive, self._sh["alive"])
        ts = jax.device_put(jnp.asarray(ts, jnp.int32), self._sh["lane"])
        te = jax.device_put(jnp.asarray(te, jnp.int32), self._sh["lane"])
        out = self.step(*self.arrays, alive, ts, te, jnp.int32(k),
                        jnp.int32(h))
        if packed:
            from repro.core.engine import pack_alive_u32

            alive_out, lo, hi, ne, iters = out
            return (pack_alive_u32(alive_out, num_vertices=v),
                    lo, hi, ne, iters)
        return out
