from repro.data.pipeline import SyntheticLMData, TCQRequestStream  # noqa: F401
