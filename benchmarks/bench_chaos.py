"""Chaos harness: seeded fault injection over the full serving stack,
gated on bit-identical results vs the fault-free run.

Every scenario replays the *same deterministic workload* (the
anti-union request set of ``bench_streaming``) through a ``TCQService``
whose engine runs the graceful-degradation ladder
(``ResilienceConfig``), with one fault class injected per scenario via
``core/faultinject.py``:

1. ``slow_lane`` — straggler steps (injected sleeps); results must not
   move, only latency.
2. ``kernel_vmem`` — the fused Pallas rung is built under a 1-byte VMEM
   budget (``interpret=False``) and is unavailable from the start: the
   ladder opens on the XLA rung and logs the demotion.
3. ``kernel_failure`` — the XLA rung raises an injected
   :class:`KernelFault` mid-pool; the ladder demotes to the oracle and
   replays the failed call bit-identically.
4. ``divergence`` — the XLA rung silently corrupts one vertex's alive
   bit; the sampled oracle tripwire catches it, quarantines the rung for
   the epoch, and replays on the oracle.
5. ``malformed_ingest`` — a stream of invalid edge batches (negative /
   overflowing / NaN / mismatched / sentinel-colliding) lands mid-run;
   each must raise :class:`GraphIngestError` and leave the graph (and
   every result) untouched.
6. ``midpool_cancel`` — one ticket is cancelled mid-pool and another
   expires via a past deadline; their lanes are reclaimed, both resolve
   with terminal statuses, and every *surviving* ticket stays
   bit-identical.
7. ``crash_restore`` — the service is snapshotted mid-queue, serialized
   through an in-memory ``.npz``, restored, and drained; the union of
   pre-crash and post-restore results must equal the uninterrupted run.

Any divergence raises (``assert_cores_equal``), so ``python -m
benchmarks.run`` — and the CI ``chaos_gate`` job (``REPRO_CHAOS=1``,
which widens the seed sweep) — fail on a broken recovery path exactly
like a wrong core.  A final closed-loop run at ~2x overload records the
shed rate and p99 under backpressure for the BENCH_wave.json ``chaos``
trajectory.
"""

from __future__ import annotations

import io
import os
import time

import numpy as np

from benchmarks.bench_streaming import disjoint_requests
from benchmarks.common import SMOKE, assert_cores_equal, emit, graph

CHAOS = os.environ.get("REPRO_CHAOS", "") not in ("", "0")
SEEDS = (0, 1, 2) if CHAOS else (0,)


def _sig(reqs):
    return [(r["k"], r.get("h", 1), r["ts"], r["te"]) for r in reqs]


def _serve(svc, reqs, poll=None):
    tickets = [svc.submit(dict(r)) for r in reqs]
    svc.run_until_idle(poll)
    return tickets


def _gate(tickets, ref, *, skip=(), ctx=""):
    """Every non-skipped ticket bit-identical to the fault-free run."""
    for i, (tk, want) in enumerate(zip(tickets, ref)):
        if i in skip:
            continue
        assert_cores_equal(tk.result, want.result,
                           ctx=f"chaos[{ctx}] req#{i}")


def _events(svc):
    return svc.engine.resilience_events()


def run_scenarios(name: str, seed: int):
    from repro.core import ResilienceConfig, TCQService
    from repro.core.faultinject import (FaultPlan, KernelFault,
                                        malformed_batches, rung_faults)
    from repro.core.graph import GraphIngestError

    g = graph(name)
    reqs = disjoint_requests(name)
    rows = []

    def scenario(tag, fn):
        t0 = time.perf_counter()
        extra = fn()
        rows.append({"bench": "chaos", "scenario": tag, "graph": name,
                     "seed": seed, "n_queries": len(reqs),
                     "equivalent": True,      # the gates above raised
                     "wall_s": time.perf_counter() - t0, **(extra or {})})

    # fault-free reference (ladder on, no injection — the ladder itself
    # must be invisible when nothing fails)
    svc0 = TCQService(g, use_kernel=False,
                      resilience=ResilienceConfig(seed=seed))
    ref = _serve(svc0, reqs)
    assert not _events(svc0), _events(svc0)

    def slow_lane():
        cfg = ResilienceConfig(seed=seed, rung_wrapper=rung_faults(
            {"xla": FaultPlan(slow_at=(0, 2, 5), delay_s=0.02)}))
        svc = TCQService(g, use_kernel=False, resilience=cfg)
        _gate(_serve(svc, reqs), ref, ctx="slow_lane")
        assert not _events(svc), _events(svc)   # stragglers never demote
        return {"demotions": 0}
    scenario("slow_lane", slow_lane)

    def kernel_vmem():
        # fused rung built under an impossible VMEM budget (and
        # interpret=False so the budget check actually runs off-TPU):
        # unavailable from call zero, ladder opens on XLA
        cfg = ResilienceConfig(seed=seed, interpret=False,
                               vmem_budget_bytes=1)
        svc = TCQService(g, use_kernel=True, resilience=cfg)
        _gate(_serve(svc, reqs), ref, ctx="kernel_vmem")
        evs = _events(svc)
        assert evs and all(e["reason"] == "vmem_budget" for e in evs), evs
        return {"demotions": len(evs), "reason": "vmem_budget"}
    scenario("kernel_vmem", kernel_vmem)

    def kernel_failure():
        cfg = ResilienceConfig(seed=seed, rung_wrapper=rung_faults(
            {"xla": FaultPlan(fail_at=(1,))}))
        svc = TCQService(g, use_kernel=False, resilience=cfg)
        _gate(_serve(svc, reqs), ref, ctx="kernel_failure")
        evs = _events(svc)
        assert any(e["reason"] == "error" for e in evs), evs
        return {"demotions": len(evs), "reason": "error"}
    scenario("kernel_failure", kernel_failure)

    def divergence():
        cfg = ResilienceConfig(seed=seed, tripwire_every=1,
                               rung_wrapper=rung_faults(
                                   {"xla": FaultPlan(corrupt_at=(0,))}))
        svc = TCQService(g, use_kernel=False, resilience=cfg)
        _gate(_serve(svc, reqs), ref, ctx="divergence")
        evs = _events(svc)
        assert any(e["reason"] == "divergence" for e in evs), evs
        return {"demotions": len(evs), "reason": "divergence"}
    scenario("divergence", divergence)

    def malformed_ingest():
        svc = TCQService(g, use_kernel=False,
                         resilience=ResilienceConfig(seed=seed))
        bad = malformed_batches(seed)
        state = {"i": 0, "rejected": 0}

        def poll(s):
            if state["i"] < len(bad):
                u, v, t = bad[state["i"]]
                state["i"] += 1
                epoch0 = s.epoch
                try:
                    s.push_edges(u, v, t)
                except GraphIngestError:
                    state["rejected"] += 1
                assert s.epoch == epoch0     # rejected batch: no epoch

        tickets = _serve(svc, reqs, poll)
        # drain any batches the poll never reached (short pools)
        while state["i"] < len(bad):
            poll(svc)
        assert state["rejected"] == len(bad), (state, len(bad))
        _gate(tickets, ref, ctx="malformed_ingest")
        return {"batches_rejected": state["rejected"]}
    scenario("malformed_ingest", malformed_ingest)

    def midpool_cancel():
        svc = TCQService(g, use_kernel=False,
                         resilience=ResilienceConfig(seed=seed))
        tickets = [svc.submit(dict(r)) for r in reqs]
        # one already-expired deadline (times out at the first sweep) ...
        doomed = svc.submit({**reqs[0], "deadline_s": -1.0})
        state = {"polls": 0}

        def poll(s):
            state["polls"] += 1
            if state["polls"] == 2:          # mid-pool: lanes are live
                s.cancel(tickets[0])         # the widest (longest) member
        svc.run_until_idle(poll)
        assert doomed.status == "timeout" and doomed.done
        assert tickets[0].status == "cancelled" and tickets[0].done
        assert tickets[0].result is not None      # partial, not missing
        _gate(tickets, ref, skip={0}, ctx="midpool_cancel")
        return {"cancelled": 1, "timeouts": 1}
    scenario("midpool_cancel", midpool_cancel)

    def crash_restore():
        svc = TCQService(g, use_kernel=False,
                         resilience=ResilienceConfig(seed=seed))
        for r in reqs:
            svc.submit(dict(r))
        early = svc.pump()                   # some resolve pre-crash
        buf = io.BytesIO()
        svc.save_snapshot(buf)               # ... crash ...
        buf.seek(0)
        from repro.core import TCQService as Svc
        svc2 = Svc.load_snapshot(buf, use_kernel=False,
                                 resilience=ResilienceConfig(seed=seed))
        late = svc2.run_until_idle()
        by_id = {tk.id: tk for tk in early + late}
        assert len(by_id) == len(reqs), (sorted(by_id), len(reqs))
        for i in range(len(reqs)):
            assert_cores_equal(by_id[i].result, ref[i].result,
                               ctx=f"chaos[crash_restore] req#{i}")
        return {"resolved_precrash": len(early),
                "resolved_postrestore": len(late)}
    scenario("crash_restore", crash_restore)

    return rows


def run_overload(name: str):
    """Closed loop at ~2x overload: concurrency far above what the
    bounded queue admits, tight deadlines — records shed rate and p99
    under backpressure (the BENCH_wave.json ``chaos`` headline)."""
    from repro.launch.serve import serve_closed_loop

    g = graph(name)
    base = disjoint_requests(name)
    n = 12 if SMOKE else 24
    reqs = [dict(base[i % len(base)]) for i in range(n)]
    svc, tickets, rep = serve_closed_loop(
        g, reqs, concurrency=16, queue_cap=8, deadline_s=30.0)
    assert rep["completed"] + rep["shed"] + rep["timeouts"] == n, rep
    # bounded p99: the deadline is the latency ceiling — a completed
    # request can never have waited past it
    assert rep["p99_ms"] <= 30_000.0, rep
    return [{"bench": "chaos_overload", "graph": name, "n_queries": n,
             "overload_x": 2.0, **rep}]


def run(name: str = "collegemsg"):
    rows = []
    for seed in SEEDS:
        rows += run_scenarios(name, seed)
    rows += run_overload(name)
    emit("bench_chaos", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
