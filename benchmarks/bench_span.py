"""Paper Fig. 12: impact of the query time span (quadratic cell count vs
output-bound OTCD)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import GRAPH_K, emit, engine, graph, timeit


def run(name: str = "collegemsg"):
    g = graph(name)
    eng = engine(name)
    k = GRAPH_K[name]
    uts = g.unique_ts
    rows = []
    base = 40
    start = uts.size // 3
    for mult in (1, 2, 3, 4, 5):
        n = base * mult
        ts = int(uts[start])
        te = int(uts[min(start + n, uts.size - 1)])
        t_otcd = timeit(lambda: eng.query(k, ts, te), repeat=2)
        t_wave = timeit(lambda: eng.query(k, ts, te, mode="wave", wave=16))
        t_tcd = timeit(lambda: eng.query(k, ts, te, algorithm="tcd"))
        res = eng.query(k, ts, te)
        rows.append({
            "graph": name, "k": k, "span_uts": n, "ts": ts, "te": te,
            "cells_total": res.stats.cells_total,
            "n_cores": len(res),
            "t_otcd_s": t_otcd, "t_otcd_wave_s": t_wave, "t_tcd_s": t_tcd,
        })
    emit("bench_span", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
