"""Launch-layer tests: HLO cost model invariants, shape-cell policies,
config registry, and roofline math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.launch import shapes as S
from repro.launch.analysis import roofline_terms
from repro.launch.hlo_cost import Collective, HLOCost


def test_hlo_cost_counts_scan_trips():
    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), ()
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((5, 64, 64), jnp.float32),
        jax.ShapeDtypeStruct((32, 64), jnp.float32)).compile()
    hc = HLOCost(comp.as_text())
    expect = 5 * 2 * 32 * 64 * 64
    assert abs(hc.flops - expect) / expect < 0.01
    # XLA's own analysis undercounts by the trip count — the reason this
    # module exists
    xla = comp.cost_analysis()
    if isinstance(xla, (list, tuple)):  # older jax returns [dict]
        xla = xla[0] if xla else {}
    assert xla.get("flops", 0) < hc.flops


def test_hlo_cost_grad_chain():
    def g(ws, x):
        h = x
        for i in range(3):
            h = jnp.tanh(h @ ws[i])
        return (h ** 2).mean()

    comp = jax.jit(jax.grad(g)).lower(
        jax.ShapeDtypeStruct((3, 128, 128), jnp.float32),
        jax.ShapeDtypeStruct((16, 128), jnp.float32)).compile()
    hc = HLOCost(comp.as_text())
    full = 3 * 3 * 2 * 16 * 128 * 128
    # fwd + bwd minus the unnecessary first-layer dx matmul = 8/9
    assert 0.85 <= hc.flops / full <= 1.0


def test_hlo_cost_slice_not_full_operand():
    """dynamic-slice traffic must be slice-sized (a scanned parameter stack
    must NOT charge the full stack per trip)."""
    def f(ws, x):
        def body(c, w):
            return c * 1.0 + w.sum(), ()
        y, _ = jax.lax.scan(body, x, ws)
        return y

    big = jax.ShapeDtypeStruct((100, 1024, 128), jnp.float32)
    comp = jax.jit(f).lower(big,
                            jax.ShapeDtypeStruct((), jnp.float32)).compile()
    hc = HLOCost(comp.as_text())
    stack_bytes = 100 * 1024 * 128 * 4
    # a handful of passes over the stack (slice materialize + re-reads),
    # NOT trips x full stack (which would be ~100x)
    assert hc.bytes < 10 * stack_bytes


def test_collective_ring_factors():
    assert Collective("all-reduce", 100, 4).ring_factor == pytest.approx(1.5)
    assert Collective("all-gather", 100, 4).ring_factor == pytest.approx(.75)
    assert Collective("collective-permute", 100, 4).ring_factor == 1.0
    assert Collective("all-reduce", 100, 1).ring_factor == 0.0


def test_roofline_terms_dominance():
    ops = [Collective("all-reduce", 8e9, 16)]
    t = roofline_terms({"flops": 1e15, "bytes accessed": 1e12}, ops,
                       model_flops_per_device=5e14)
    assert t["dominant"] in ("compute", "memory", "collective")
    assert t["t_memory_s"] == pytest.approx(1e12 / 819e9)
    assert t["useful_compute_ratio"] == pytest.approx(0.5)
    assert 0 < t["roofline_fraction"] <= 1.0


def test_long_context_policy():
    """long_500k runs exactly for the sub-quadratic families."""
    runs = {a for a in list_archs()
            if S.cell_is_applicable(get_config(a), "long_500k")[0]}
    assert runs == {"rwkv6-1.6b", "jamba-1.5-large-398b"}
    for a in list_archs():
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            assert S.cell_is_applicable(get_config(a), shape)[0]


def test_registry_complete():
    assert len(list_archs()) == 10
    for a in list_archs():
        cfg = get_config(a)
        assert cfg.param_count() > 0
        assert cfg.scan_period() >= 1
        assert cfg.n_layers % cfg.scan_period() == 0


def test_shape_cells_match_assignment():
    assert S.SHAPES["train_4k"].seq == 4096
    assert S.SHAPES["train_4k"].batch == 256
    assert S.SHAPES["prefill_32k"] == S.ShapeCell("prefill_32k", 32768, 32,
                                                  "prefill")
    assert S.SHAPES["decode_32k"].batch == 128
    assert S.SHAPES["long_500k"].seq == 524_288
    assert S.SHAPES["long_500k"].batch == 1


def test_microbatch_policy():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    big = get_config("jamba-1.5-large-398b")
    small = get_config("granite-moe-1b-a400m")
    cell = S.SHAPES["train_4k"]
    assert S.microbatches(big, cell, mesh) >= S.microbatches(
        small, cell, mesh)
    assert S.microbatches(big, S.SHAPES["decode_32k"], mesh) == 1


def test_tcq_configs_cover_paper_scales():
    from repro.configs import get_tcq_config, list_tcq_configs

    names = list_tcq_configs()
    assert "tcq-stackoverflow" in names and "tcq-billion" in names
    bil = get_tcq_config("tcq-billion")
    assert bil.num_edges >= 1_000_000_000  # the paper's "needs a cluster"
