"""Pallas banded-segsum kernel vs the pure-jnp oracle: shape/dtype sweeps.

The kernel runs in interpret mode on CPU (the TPU is the target; interpret
executes the same kernel body).  Sweeps cover ragged sizes, empty segments,
hub segments (band wider than one tile), padding tails, and dtypes.
"""

import os

import numpy as np
import pytest
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: vendored seeded fallback
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.segdeg.kernel import banded_segsum_pallas, required_k_max
from repro.kernels.segdeg.ops import make_banded_segsum
from repro.kernels.segdeg.ref import banded_segsum_ref


def _run(vals, segs, s):
    k_max = required_k_max(segs, s)
    out = banded_segsum_pallas(jnp.asarray(vals), jnp.asarray(segs),
                               num_segments=s, k_max=k_max, interpret=True)
    ref = banded_segsum_ref(jnp.asarray(vals.astype(np.float32)),
                            jnp.asarray(segs), s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,s,q", [
    (1, 1, 1),            # degenerate
    (100, 7, 3),          # tiny ragged
    (1000, 300, 17),      # ragged everything
    (512, 128, 128),      # exactly tile-aligned
    (513, 129, 129),      # one past tile boundaries
    (4096, 1024, 64),     # multi-tile
    (2048, 4, 8),         # few fat segments (wide band)
])
def test_shapes_vs_ref(n, s, q):
    rng = np.random.default_rng(n + s + q)
    segs = np.sort(rng.integers(0, s, n)).astype(np.int32)
    vals = rng.normal(0, 1, (n, q)).astype(np.float32)
    _run(vals, segs, s)


@pytest.mark.parametrize("dtype", [np.float32, np.float16, np.int32])
def test_dtypes(dtype):
    rng = np.random.default_rng(3)
    n, s, q = 700, 150, 9
    segs = np.sort(rng.integers(0, s, n)).astype(np.int32)
    if dtype == np.int32:
        vals = rng.integers(0, 3, (n, q)).astype(dtype)
    else:
        vals = rng.normal(0, 1, (n, q)).astype(dtype)
    _run(vals.astype(np.float32), segs, s)


def test_empty_segments_and_gaps():
    segs = np.array([0, 0, 5, 5, 5, 299], dtype=np.int32)
    vals = np.ones((6, 4), dtype=np.float32)
    _run(vals, segs, 300)


def test_hub_segment_band_wider_than_tile():
    """One segment owns most rows => its output tile spans many input
    tiles (the k_max dimension does real work)."""
    n, s, q = 3000, 50, 5
    segs = np.concatenate([np.zeros(2500, np.int32),
                           np.sort(np.random.default_rng(0).integers(
                               1, s, 500)).astype(np.int32)])
    segs = np.sort(segs)
    vals = np.random.default_rng(1).normal(0, 1, (n, q)).astype(np.float32)
    assert required_k_max(segs, s) > 1
    _run(vals, segs, s)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 400), st.integers(1, 90), st.integers(1, 12),
       st.integers(0, 2 ** 31 - 1))
def test_property_random(n, s, q, seed):
    rng = np.random.default_rng(seed)
    segs = np.sort(rng.integers(0, s, n)).astype(np.int32)
    vals = rng.normal(0, 1, (n, q)).astype(np.float32)
    _run(vals, segs, s)


def test_dispatcher_falls_back_on_wide_band():
    segs = np.zeros(100_000, dtype=np.int32)  # one massive hub
    fn = make_banded_segsum(segs, 4, k_cap=4)
    vals = np.ones((100_000, 2), dtype=np.float32)
    out = fn(jnp.asarray(vals), jnp.asarray(segs))
    assert float(out[0, 0]) == 100_000.0


def test_wave_engine_with_kernel_matches_xla():
    """tcd_wave with the Pallas degree path == the XLA segment_sum path."""
    import jax

    from repro.core.wave import make_segsum_fns, tcd_wave
    from repro.graphs import planted_cores

    g = planted_cores(seed=5)
    tel = g.device_tel()
    ts = jnp.asarray([1, 5, 10], jnp.int32)
    te = jnp.asarray([40, 30, 25], jnp.int32)
    alive0 = jnp.ones((3, g.num_vertices), dtype=bool)
    outs = []
    for use_kernel in (False, True):
        sp, sv = make_segsum_fns(g, use_kernel=use_kernel)
        res = tcd_wave(tel, alive0, ts, te, 3, 1,
                       num_vertices=g.num_vertices, seg_pair=sp, seg_vert=sv)
        outs.append(res)
    np.testing.assert_array_equal(np.asarray(outs[0].alive),
                                  np.asarray(outs[1].alive))
    np.testing.assert_array_equal(np.asarray(outs[0].tti_lo),
                                  np.asarray(outs[1].tti_lo))
    np.testing.assert_array_equal(np.asarray(outs[0].n_edges),
                                  np.asarray(outs[1].n_edges))


def test_wave_engine_matches_oracle():
    from repro.core.oracle import peel_window
    from repro.core.wave import make_segsum_fns, tcd_wave
    from repro.graphs import powerlaw_temporal

    g = powerlaw_temporal(50, 300, 40, seed=4)
    tel = g.device_tel()
    sp, sv = make_segsum_fns(g, use_kernel=True)
    ts = [1, 3, 8]
    te = [40, 20, 30]
    res = tcd_wave(tel, jnp.ones((3, g.num_vertices), bool),
                   jnp.asarray(ts, jnp.int32), jnp.asarray(te, jnp.int32),
                   2, 1, num_vertices=g.num_vertices,
                   seg_pair=sp, seg_vert=sv)
    for i in range(3):
        em = peel_window(g, ts[i], te[i], 2)
        verts = (set(np.unique(np.concatenate(
            [g.src[em], g.dst[em]])).tolist()) if em.any() else set())
        got = set(np.flatnonzero(np.asarray(res.alive[i])).tolist())
        assert got == verts


# ------------------------------------------------- fused wave-peel kernel
# Seeded equivalence fuzz: the fused Pallas peel-to-fixpoint kernel
# (interpret mode on CPU — same kernel body as the TPU lowering) must be
# BIT-identical to the XLA composite on every StepResult field.  This is
# the correctness gate behind `BENCH_wave.json`'s kernel section and the
# CI `kernel_gate` job; REPRO_KERNEL_GATE=1 widens the sweep.

_FUZZ_SEEDS = range(24 if os.environ.get("REPRO_KERNEL_GATE") == "1" else 6)


def _random_temporal_graph(rng):
    from repro.core.graph import TemporalGraph

    v = int(rng.integers(3, 60))
    e = int(rng.integers(5, 400))
    tmax = int(rng.integers(4, 60))
    u = rng.integers(0, v, e)
    w = rng.integers(0, v, e)
    keep = u != w
    u, w = u[keep], w[keep]
    if u.size == 0:
        u, w = np.array([0]), np.array([v - 1])
    t = rng.integers(0, tmax, u.size)
    return TemporalGraph.from_edges(u, w, t, num_vertices=v), tmax


def _fuzz_fused_vs_composite(seed, *, capacity_padding):
    from repro.core.graph import pow2_capacity
    from repro.core.wave import make_wave_step_fn, unpack_alive_u32

    rng = np.random.default_rng(seed)
    g, tmax = _random_temporal_graph(rng)
    if capacity_padding:
        # capacity-class TEL: sentinel edges (t=int32 min, pair_id=P_cap)
        # and sentinel half-pairs (hp_src=V_cap) in every table tail
        nv = pow2_capacity(g.num_vertices)
        tel = g.device_tel(edge_capacity=pow2_capacity(g.num_edges),
                           pair_capacity=pow2_capacity(g.num_pairs),
                           vertex_capacity=nv)
    else:
        nv = g.num_vertices
        tel = g.device_tel()
    w_tile = int(rng.choice([4, 8]))
    fused = make_wave_step_fn(tel, nv, use_kernel=True, w_tile=w_tile)
    comp = make_wave_step_fn(tel, nv, use_kernel=False)
    assert fused.backend == "pallas" and fused.interpret
    assert comp.backend == "xla"

    W = int(rng.integers(1, 12))     # rarely a w_tile multiple
    ts = rng.integers(0, tmax, W).astype(np.int32)
    te = (ts + rng.integers(0, tmax, W)).astype(np.int32)
    empty = rng.random(W) < 0.25     # pipeline-style idle padding lanes
    ts[empty], te[empty] = 0, -1
    k = rng.integers(1, 5, W).astype(np.int32)
    h = rng.integers(1, 3, W).astype(np.int32)
    if rng.random() < 0.5:
        alive = jnp.asarray(rng.random((W, nv)) < 0.8)   # warm-start rows
    else:
        alive = jnp.ones((W, nv), dtype=bool)

    args = (alive, jnp.asarray(ts), jnp.asarray(te),
            jnp.asarray(k), jnp.asarray(h))
    rf, rc = fused(*args), comp(*args)
    for field in ("alive", "packed", "tti_lo", "tti_hi", "n_edges", "iters"):
        np.testing.assert_array_equal(
            np.asarray(getattr(rf, field)), np.asarray(getattr(rc, field)),
            err_msg=f"fused vs composite diverge on {field} (seed={seed})")
    assert np.asarray(rf.packed).dtype == np.uint32
    np.testing.assert_array_equal(
        unpack_alive_u32(np.asarray(rf.packed), nv), np.asarray(rf.alive))


@pytest.mark.kernel_gate
@pytest.mark.parametrize("seed", _FUZZ_SEEDS)
def test_fused_wave_peel_matches_composite(seed):
    _fuzz_fused_vs_composite(1000 + seed, capacity_padding=False)


@pytest.mark.kernel_gate
@pytest.mark.parametrize("seed", _FUZZ_SEEDS)
def test_fused_wave_peel_matches_composite_capacity_padded(seed):
    _fuzz_fused_vs_composite(2000 + seed, capacity_padding=True)


@pytest.mark.kernel_gate
def test_fused_step_through_tcd_wave():
    """The step_fn route of tcd_wave == the jitted XLA route, including
    the derived n_verts, on a planted-cores graph."""
    from repro.core.wave import make_segsum_fns, make_wave_step_fn, tcd_wave
    from repro.graphs import planted_cores

    g = planted_cores(seed=11)
    tel = g.device_tel()
    sp, sv = make_segsum_fns(g, use_kernel=False)
    step = make_wave_step_fn(tel, g.num_vertices, use_kernel=True)
    ts = jnp.asarray([1, 5, 0], jnp.int32)
    te = jnp.asarray([40, 30, -1], jnp.int32)
    k = jnp.asarray([3, 2, 1], jnp.int32)
    h = jnp.asarray([1, 1, 1], jnp.int32)
    alive0 = jnp.ones((3, g.num_vertices), dtype=bool)
    ref = tcd_wave(tel, alive0, ts, te, k, h, num_vertices=g.num_vertices,
                   seg_pair=sp, seg_vert=sv)
    got = tcd_wave(tel, alive0, ts, te, k, h, num_vertices=g.num_vertices,
                   step_fn=step)
    for field in ("alive", "tti_lo", "tti_hi", "n_edges", "n_verts", "iters"):
        np.testing.assert_array_equal(np.asarray(getattr(got, field)),
                                      np.asarray(getattr(ref, field)))


def test_fused_vmem_budget_falls_back_to_composite():
    """A TEL whose working set exceeds the VMEM budget must yield the
    composite from the dispatcher (never a kernel that can't fit)."""
    from repro.core.wave import make_wave_step_fn
    from repro.graphs import planted_cores

    g = planted_cores(seed=3)
    tel = g.device_tel()
    step = make_wave_step_fn(tel, g.num_vertices, use_kernel=True,
                             interpret=False, vmem_budget_bytes=1024)
    assert step.backend == "xla"


def test_segsum_fns_cached_per_epoch():
    """make_segsum_fns: same (graph, epoch, path) => same closures; a
    streaming append (new epoch) refreshes them."""
    from repro.core.wave import make_segsum_fns
    from repro.graphs import planted_cores

    g = planted_cores(seed=9)
    a = make_segsum_fns(g, use_kernel=False)
    b = make_segsum_fns(g, use_kernel=False)
    assert a == b
    assert make_segsum_fns(g, use_kernel=True) != a
    g2 = g.add_edges([0], [1], [99])
    assert g2.epoch != g.epoch
    assert make_segsum_fns(g2, use_kernel=False) != a


# ---------------------------------------------------------------- ssm scan
def test_ssm_scan_kernel_matches_ref():
    """Pallas diagonal-SSM scan (VMEM-resident state) vs the lax.scan
    oracle — the register-residency fix identified in EXPERIMENTS §Perf B."""
    from repro.kernels.ssm_scan.kernel import ssm_scan_pallas
    from repro.kernels.ssm_scan.ref import ssm_scan_ref

    rng = np.random.default_rng(7)
    for b, s, f, sc, ft in [(1, 5, 3, 4, 128), (2, 300, 700, 64, 256),
                            (3, 128, 512, 128, 512)]:
        la = jnp.asarray(-np.abs(rng.normal(0.3, 0.5, (b, s, f))),
                         jnp.float32)
        bx = jnp.asarray(rng.normal(0, 1, (b, s, f)), jnp.float32)
        s0 = jnp.asarray(rng.normal(0, 1, (b, f)), jnp.float32)
        out = ssm_scan_pallas(la, bx, s0, s_chunk=sc, f_tile=ft,
                              interpret=True)
        ref = ssm_scan_ref(la, bx, s0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
