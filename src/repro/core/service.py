"""Streaming TCQ service runtime: continuous query traffic over a living
temporal graph.

``TCQEngine.query_batch`` answers a *fixed* request set behind a drain
barrier — admit, run, return.  A serving system sees neither fixed sets
nor a frozen graph: requests arrive while earlier ones are still peeling,
and `EdgeStream.push` batches land between (and during) waves.  This
module owns that continuous loop:

* **Tickets and epoch pinning** — :meth:`TCQService.submit` stamps each
  request with the engine epoch *and the graph snapshot* current at
  admission.  Snapshots are immutable (``add_edges`` returns a new
  ``TemporalGraph``), so pinning is a reference, not a copy; a query
  admitted at epoch e is answered exactly over epoch e's edges no matter
  how many ingestion batches land while it runs (snapshot consistency —
  results are bit-identical to querying the pinned snapshot alone).

* **Window-clustered lane pools** — co-admitted requests are grouped by
  window overlap (:func:`cluster_windows`), and each cluster peels
  against a TEL truncated to *its own* union window instead of one
  bloated global union.  Disjoint far-apart windows — the worst case for
  ``query_batch``'s single union TEL, whose per-iteration peel cost
  scales with the union's edge count — become separate tight pools.

* **Mid-flight admission** — each pool runs through
  ``WavePipeline.run_pool(..., admit=...)``: whenever lanes free up, the
  service's admit hook (optionally after polling the driver for new
  arrivals/ingestion) admits every pending ticket whose epoch matches
  the pool and whose window fits inside the pool's TEL.  Lanes freed by
  a draining query's tail are refilled by *newly arrived* queries with
  no barrier in between; tickets that don't fit the live pool are served
  by the next ``pump``.

The driver loop is deliberately synchronous and single-device (the
repo's serving story is one engine per accelerator); ``poll`` callbacks
are the seam where a real frontend — or the open-loop benchmark drivers
in ``launch/serve.py`` / ``benchmarks/bench_streaming.py`` — injects
arrivals and edge ingestion mid-flight.
"""

from __future__ import annotations

import dataclasses
import json
import time
from collections import Counter, deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import wal as walmod
from repro.core.graph import TemporalGraph
from repro.core.otcd import TCQEngine
from repro.core.results import QueryStats, TCQResult
from repro.core.scheduler import QueryState


# ---------------------------------------------------------------- clustering
def cluster_windows(windows: Sequence[Tuple[int, int]],
                    gap: int = 0) -> List[List[int]]:
    """Group window indices by overlap (union-find via interval sweep).

    Windows whose intervals overlap — or sit within ``gap`` of each other
    — land in one cluster; the result is a partition of ``range(len)``
    ordered by cluster start.  O(n log n).  A cluster's union window is
    exactly the union of its members, so each cluster's TEL truncation
    is tight: no member pays for edges only another cluster needs.
    """
    if not windows:
        return []
    order = sorted(range(len(windows)), key=lambda i: windows[i])
    clusters: List[List[int]] = [[order[0]]]
    hi = windows[order[0]][1]
    for i in order[1:]:
        lo_i, hi_i = windows[i]
        if lo_i <= hi + gap:
            clusters[-1].append(i)
            hi = max(hi, hi_i)
        else:
            clusters.append([i])
            hi = hi_i
    return clusters


# -------------------------------------------------------------------- ticket
#: terminal ticket statuses — ``done`` (full result), ``timeout`` (deadline
#: passed; partial result of whatever cells completed), ``cancelled``
#: (client withdrawal, same partial-result contract), ``shed`` (dropped by
#: the frontend's load shedder before admission).
TERMINAL_STATUSES = ("done", "timeout", "cancelled", "shed")


@dataclasses.dataclass
class TCQTicket:
    """One in-flight (or completed) service request.

    ``epoch``/``graph`` pin the TEL snapshot current at admission: the
    result is computed over exactly those edges, regardless of ingestion
    that lands later.  ``uts`` is the snapshot's unique-timestamp slice
    for the window (the schedule's column space), fixed at submit time.

    ``deadline`` is an *absolute* ``time.perf_counter()`` instant (None =
    best-effort); ``priority`` breaks deadline ties, lower first.  The
    pair drives both pool formation (EDF head-of-line) and in-pool lane
    claiming (:class:`~repro.core.scheduler.QueryState`'s EDF key).
    Lifecycle: ``queued`` → ``running`` → one of
    :data:`TERMINAL_STATUSES`.
    """

    id: int
    k: int
    h: int
    ts: int
    te: int
    epoch: int
    graph: TemporalGraph
    uts: np.ndarray
    submit_s: float
    priority: int = 0
    deadline: Optional[float] = None
    status: str = "queued"
    admit_s: Optional[float] = None
    done_s: Optional[float] = None
    result: Optional[TCQResult] = None
    state: Optional[QueryState] = None

    @property
    def done(self) -> bool:
        return self.status in TERMINAL_STATUSES

    @property
    def edf_key(self) -> Tuple[float, int, int]:
        """Earliest-deadline-first ordering key (ties: priority, then
        arrival order — (inf, 0, id) degenerates to exact FIFO)."""
        d = self.deadline if self.deadline is not None else float("inf")
        return (d, self.priority, self.id)

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now > self.deadline

    @property
    def latency_s(self) -> Optional[float]:
        """Submit-to-completion latency (the serving metric)."""
        if self.done_s is None:
            return None
        return self.done_s - self.submit_s

    @property
    def window(self) -> Tuple[int, int]:
        """Schedule-tight window: the snapshot timestamps actually swept."""
        return int(self.uts[0]), int(self.uts[-1])


# ------------------------------------------------------------------- service
class TCQService:
    """Continuous multi-tenant TCQ serving over a streaming graph.

    Parameters
    ----------
    graph:
        Initial snapshot (or pass ``engine=`` to wrap an existing one).
    wave:
        Lane count per pool, or ``"auto"`` (default) — autotuned per pool
        from the cluster's union-window edge count, member count and ring
        depth.
    depth:
        Slot-ring depth D of each pool's pipeline.
    cluster_gap:
        Two windows whose gap is <= this many time units still share a
        cluster (0 = pure overlap).  Small positive values trade a
        slightly looser TEL for fewer, fuller pools.
    cache:
        TTI-keyed core-result caching (``corecache.CoreCache``) for
        engines the service builds itself: True (default) builds one,
        False disables it, an instance is used as-is.  Ignored when an
        external ``engine=`` is passed — its own ``cache`` setting wins
        (wrapping a shared engine must not change its semantics).
        Admission probes the cache before pool formation, so a request
        whose every cell resolves never joins a pool (and never widens a
        cluster's union window); peeled cells are inserted as they
        retire; ingest invalidates incrementally (see ``update_graph``).
    wal_dir / fsync / wal:
        Durability (``core.wal``).  ``wal_dir`` attaches a write-ahead
        journal: every accepted mutation — edge batch, ticket admission,
        cancellation, external snapshot install — is logged *before* it
        is applied, so :meth:`recover` can rebuild the exact pre-crash
        state from the newest valid snapshot plus the journal tail.
        ``fsync`` picks the flush policy (``always``/``batch``/``off``,
        see :class:`~repro.core.wal.WriteAheadLog`).  ``wal=`` accepts a
        pre-built (or fault-injecting) log instance directly and wins
        over ``wal_dir``.  If the directory holds no snapshot yet, a
        genesis checkpoint of the initial graph is written so recovery
        is always total.  Default (all None): no journal — the PR 5
        snapshot-only behavior.

    Usage::

        svc = TCQService(graph)
        t1 = svc.submit({"k": 3, "ts": 10, "te": 500})
        svc.push_edges(u, v, t)                  # new epoch; t1 unaffected
        t2 = svc.submit({"k": 2, "ts": 40, "te": 90})   # sees new edges
        svc.run_until_idle()
        t1.result, t1.latency_s

    ``pump(poll=...)`` serves one cluster-pool; ``poll`` is invoked
    between waves (whenever lanes free) so the driver can submit new
    requests or push edges *mid-flight* — compatible arrivals join the
    running pool immediately.
    """

    def __init__(self, graph: Optional[TemporalGraph] = None, *,
                 engine: Optional[TCQEngine] = None,
                 wave="auto", depth: int = 2, cluster_gap: int = 0,
                 use_kernel: Optional[bool] = None,
                 retain_snapshots: bool = True,
                 resilience=None, cache=True,
                 mesh=None, combine: str = "auto",
                 wal_dir: Optional[str] = None, fsync: str = "batch",
                 wal=None):
        if engine is None:
            if graph is None:
                raise ValueError("need a graph or an engine")
            engine = TCQEngine(graph, use_kernel=use_kernel,
                               resilience=resilience, cache=cache,
                               mesh=mesh, combine=combine)
        self.engine = engine
        self.wave = wave
        self.depth = int(depth)
        self.cluster_gap = int(cluster_gap)
        # --- durability: write-ahead journal (core.wal).  _replaying
        # suppresses the hooks while recover() feeds journal records back
        # through the very paths that wrote them.
        self._replaying = False
        self.recovery_report: Optional[Dict] = None
        if wal is not None:
            self.wal = wal
        elif wal_dir is not None:
            self.wal = walmod.WriteAheadLog(wal_dir, fsync=fsync)
        else:
            self.wal = None
        self.retained_checkpoints = 2   # corrupt-newest fallback stays lossless
        # arrival-process window histogram: (k, h, ts, te) -> count.
        # prewarm() peels the hottest uncached windows during idle time so
        # recurring traffic hits a warm cache.
        self._hist: Counter = Counter()
        self._prewarmed = 0
        # False drops each ticket's pinned graph reference once it
        # completes, so a long-running service does not hold one O(E)
        # snapshot per epoch alive through its history (the driver owns
        # trimming ``completed``/``pool_log`` themselves)
        self.retain_snapshots = bool(retain_snapshots)
        self._pending: Deque[TCQTicket] = deque()
        self._fresh: List[TCQTicket] = []   # resolved-at-submit tickets
        # live pool members (pump removes them from _pending while lanes
        # run) — snapshot() must still see the unresolved ones, or a
        # checkpoint taken from a mid-pool poll/admit hook would drop them
        self._inflight: List[TCQTicket] = []
        self.completed: List[TCQTicket] = []
        self._next_id = 0
        self.pool_log: List[Dict] = []      # one record per pool run
        if (self.wal is not None
                and not walmod.list_snapshots(self.wal.dir)):
            # genesis checkpoint: a directory with no snapshot would make
            # recover() partial (nothing to replay the tail onto), so the
            # initial graph is persisted at the active sequence number —
            # every later journal record lands in a segment >= it
            self._write_snapshot_file(self.wal.active_seq)

    def _journal(self, kind: str, meta: Dict, arrays=None) -> None:
        """Append one write-ahead record (no-op without a journal, and
        during :meth:`recover`'s replay of the very records being read)."""
        if self.wal is not None and not self._replaying:
            self.wal.append(kind, meta, arrays)

    # ------------------------------------------------------------- ingestion
    @property
    def epoch(self) -> int:
        return self.engine.epoch

    @property
    def graph(self) -> TemporalGraph:
        return self.engine.graph

    def push_edges(self, u, v, t) -> int:
        """Merge-append an arrival batch; returns the new epoch.  O(E+B)
        host work; in-flight/pending tickets keep their pinned snapshot.

        With a journal attached, the batch is logged *after* validation
        (``add_edges`` raising means the batch was never accepted — a
        rejected batch must not be replayed) but *before* the engine
        installs the new epoch, together with the post-state the replay
        must reproduce (edge/pair/vertex counts and the canonical-array
        fingerprint — the lineage check, since ``uid`` is process-local).
        """
        g = self.engine.graph.add_edges(u, v, t)
        if g is self.engine.graph:          # empty/self-loop-only batch
            return self.engine.epoch
        if self.wal is not None and not self._replaying:
            self._journal("edges", {
                "graph_epoch": int(g.epoch),
                "num_edges": g.num_edges, "num_pairs": g.num_pairs,
                "num_vertices": int(g.num_vertices),
                "fingerprint": g.fingerprint(),
            }, {"u": np.asarray(u), "v": np.asarray(v),
                "t": np.asarray(t)})
        return self.engine.update_graph(g)

    def ingest_graph(self, graph: TemporalGraph) -> int:
        """Install an externally built snapshot (``EdgeStream`` subscriber
        form: ``stream.subscribe(svc.ingest_graph)``).  Journaled as the
        graph's full canonical state (there is no batch to re-derive it
        from), fingerprint-checked on replay like :meth:`push_edges`."""
        if self.wal is not None and not self._replaying:
            self._journal("install", {
                "graph_epoch": int(graph.epoch),
                "num_vertices": int(graph.num_vertices),
                "fingerprint": graph.fingerprint(),
            }, graph.state_dict())
        return self.engine.update_graph(graph)

    def connect(self, stream) -> None:
        """Subscribe to an ``EdgeStream`` so pushes land as new epochs."""
        stream.subscribe(self.ingest_graph)

    # ------------------------------------------------------------ submission
    def submit(self, request) -> TCQTicket:
        """Admit one request; returns its ticket (resolved immediately for
        windows containing no snapshot timestamps).

        ``request`` is a mapping with ``k``, ``ts``, ``te`` and optional
        ``h``, ``priority`` (lower runs first) and ``deadline_s``
        (seconds from submission; the ticket is cancelled — with partial
        results — once it passes) — the ``TCQRequestStream`` format.
        """
        r = dict(request)
        now = time.perf_counter()
        g = self.engine.graph
        uts = g.unique_ts
        uts = uts[(uts >= int(r["ts"])) & (uts <= int(r["te"]))]
        uts = uts.astype(np.int64)
        dl = r.get("deadline_s")
        # write-ahead: the admission record precedes the enqueue, so a
        # crash between the two replays the admission (at-least-once;
        # results are deterministic in the request + pinned epoch).
        # ids are sequential and every admission is journaled, so replay
        # reproduces them exactly (recover() asserts this).
        self._journal("submit", {
            "id": int(self._next_id), "k": int(r["k"]),
            "h": int(r.get("h", 1)), "ts": int(r["ts"]),
            "te": int(r["te"]), "priority": int(r.get("priority", 0)),
            "deadline_s": None if dl is None else float(dl),
            "submit_unix_s": time.time(),
        })
        tk = TCQTicket(id=self._next_id, k=int(r["k"]),
                       h=int(r.get("h", 1)), ts=int(r["ts"]),
                       te=int(r["te"]), epoch=self.engine.epoch, graph=g,
                       uts=uts, submit_s=now,
                       priority=int(r.get("priority", 0)),
                       deadline=None if dl is None else now + float(dl))
        self._next_id += 1
        n = int(uts.size)
        if n == 0:
            tk.result = TCQResult([], QueryStats(n_timestamps=0))
            tk.status = "done"
            tk.admit_s = tk.done_s = now
            tk.result.stats.wall_time_s = 0.0
            self._retire(tk)
            self._fresh.append(tk)      # handed back by the next pump()
            return tk
        self._hist[(tk.k, tk.h, tk.ts, tk.te)] += 1
        self._pending.append(tk)
        return tk

    @property
    def pending(self) -> int:
        return len(self._pending)

    @property
    def pending_tickets(self) -> Tuple[TCQTicket, ...]:
        return tuple(self._pending)

    # ------------------------------------------------- cancellation/deadlines
    def cancel(self, tk: TCQTicket, *, status: str = "cancelled") -> bool:
        """Withdraw a ticket (client cancel / deadline timeout / shed).

        Queued tickets resolve immediately with an empty partial result;
        a *running* ticket is flagged so the live pool reclaims its lanes
        at the next wave and finalizes it with whatever cells already
        completed.  False if the ticket had already resolved.
        """
        if tk.done:
            return False
        self._journal("cancel", {"id": int(tk.id), "status": str(status)})
        now = time.perf_counter()
        tk.status = status
        if tk.state is not None:
            tk.state.cancel()           # pool frees its lanes mid-flight
        if tk in self._pending:         # queued: resolve on the spot
            self._pending.remove(tk)
            self._resolve_unrun(tk, now)
        return True

    def _resolve_unrun(self, tk: TCQTicket, now: float) -> None:
        """Terminal bookkeeping for a ticket cancelled before it ever
        held a lane (no state to decode — empty partial result)."""
        st = QueryStats(n_timestamps=int(tk.uts.size))
        st.wall_time_s = now - tk.submit_s
        tk.result = TCQResult([], st)
        tk.done_s = now
        self._retire(tk)
        self._fresh.append(tk)          # handed back by the next pump()

    def expire(self, now: Optional[float] = None) -> List[TCQTicket]:
        """Time out every *queued* ticket past its deadline (running
        tickets are swept by the live pool's admit hook).  Returns the
        newly timed-out tickets."""
        now = time.perf_counter() if now is None else now
        hit = [tk for tk in self._pending if tk.expired(now)]
        for tk in hit:
            self.cancel(tk, status="timeout")
        return hit

    # --------------------------------------------------------------- serving
    def _build_state(self, tk: TCQTicket) -> QueryState:
        """The ticket's QueryState, created on first need.  An existing
        state (from an admission-time cache probe) is reused so cells it
        already resolved are never re-probed or re-peeled."""
        if tk.state is None:
            n = int(tk.uts.size)
            stats = QueryStats(n_timestamps=n,
                               cells_total=n * (n + 1) // 2)
            dl = float("inf") if tk.deadline is None else tk.deadline
            tk.state = QueryState(
                tk.uts, tk.k, tk.h, True, stats, qid=tk.id,
                deadline=dl, priority=tk.priority,
                cache=self.engine._cache_view(tk.k, tk.h, tk.epoch))
        return tk.state

    def _make_state(self, tk: TCQTicket) -> QueryState:
        st = self._build_state(tk)
        tk.status = "running"
        tk.admit_s = time.perf_counter()
        return st

    def _try_cache_resolve(self, tk: TCQTicket, now: float) -> bool:
        """Admission-time cache lookup: resolve the ticket's schedule as
        far as the TTI cache reaches; True iff it completed entirely from
        cache (the ticket never joins a pool).  Each ticket is probed
        once — partial progress is kept on its state, and the lane pool's
        claim path re-probes naturally as new entries land."""
        st = self._build_state(tk)
        st.resolve_cached()
        if not st.done:
            return False
        tk.status = "running"
        tk.admit_s = now
        self._finalize(tk, self.engine.num_vertices, time.perf_counter())
        return True

    def _retire(self, tk: TCQTicket) -> None:
        """Bookkeeping for a ticket that just resolved."""
        tk.state = None             # drop packed rows + pruning state
        if not self.retain_snapshots:
            tk.graph = None
        self.completed.append(tk)

    def _finalize(self, tk: TCQTicket, num_vertices: int,
                  done_s: float) -> None:
        cores = tk.state.decode_results(num_vertices)
        st = tk.state.stats
        tk.result = TCQResult(list(cores.values()), st)
        tk.done_s = done_s
        st.wall_time_s = done_s - tk.submit_s
        if tk.status not in TERMINAL_STATUSES:   # cancel/timeout keep theirs
            tk.status = "done"
        self._retire(tk)

    def pump(self, poll: Optional[Callable[["TCQService"], None]] = None
             ) -> List[TCQTicket]:
        """Serve one window-clustered pool to completion; returns every
        ticket resolved along the way (including requests resolved at
        submit time for empty windows).  ``poll`` is called before pool
        formation and again every time lanes free up, so the driver can
        inject arrivals and ingestion mid-flight; arrivals that match
        the live pool's epoch and fit its union window are admitted into
        it, the rest wait for the next pump.  Tickets resolve *as their
        own schedule drains* — a query admitted early is not held open
        by queries admitted after it, so per-ticket latency is honest
        even when sustained arrivals keep one pool alive.  Returns []
        when nothing resolved and nothing is pending.
        """
        if poll is not None:
            poll(self)
        self.expire()
        if self.wal is not None:
            # batch fsync barrier: everything journaled since the last
            # pump (arrivals, ingest from the poll hook) becomes durable
            # before the pool claims the device
            self.wal.sync()
        if self.engine.core_cache is not None:
            # admission-time lookup: tickets served entirely by the TTI
            # cache resolve here — they never join a pool, never widen a
            # cluster's union window, and never touch the device
            now = time.perf_counter()
            for tk in [t for t in self._pending if t.state is None]:
                if self._try_cache_resolve(tk, now):
                    self._pending.remove(tk)
                    self._fresh.append(tk)
        if not self._pending:
            fresh, self._fresh = self._fresh, []
            return fresh
        # EDF head-of-line: the most urgent (deadline, priority) ticket
        # picks the pool; with no deadlines/priorities the key degenerates
        # to arrival order, i.e. the old FIFO head — older snapshots drain
        # first so pinned epochs (and their cached TELs) retire quickly
        head = min(self._pending, key=lambda t: t.edf_key)
        epoch = head.epoch
        cand = [tk for tk in self._pending if tk.epoch == epoch]
        clusters = cluster_windows([tk.window for tk in cand],
                                   self.cluster_gap)
        members = next(
            [cand[i] for i in c] for c in clusters
            if any(cand[i] is head for i in c))
        for tk in members:
            self._pending.remove(tk)
        self._inflight = members    # same list object: grows with admits
        pool_lo = min(tk.window[0] for tk in members)
        pool_hi = max(tk.window[1] for tk in members)
        pipe, wt, wave = self.engine.make_pool(
            pool_lo, pool_hi, graph=head.graph, epoch=epoch,
            num_queries=len(members), wave=self.wave, depth=self.depth)
        states = [self._make_state(tk) for tk in members]
        pool_stats = QueryStats()
        t0 = time.perf_counter()

        def admit() -> List[QueryState]:
            if poll is not None:
                poll(self)
            now = time.perf_counter()
            self.expire(now)
            for tk in members:
                # deadline sweep over *running* members: flag the state so
                # run_pool reclaims its lanes at this very wave boundary
                if (tk.done_s is None and tk.status == "running"
                        and tk.expired(now)):
                    tk.status = "timeout"
                    tk.state.cancel()
                # resolve members whose own schedule has fully drained —
                # their latency must not absorb later admissions' work
                if tk.done_s is None and tk.state.done:
                    self._finalize(tk, wt.num_vertices, now)
            newly = []
            for tk in list(self._pending):
                if (tk.epoch == epoch and tk.window[0] >= pool_lo
                        and tk.window[1] <= pool_hi):
                    self._pending.remove(tk)
                    members.append(tk)
                    st = self._make_state(tk)
                    # a mid-flight arrival fully served by the cache
                    # resolves on the spot instead of taking lanes
                    st.resolve_cached()
                    if st.done:
                        self._finalize(tk, wt.num_vertices, now)
                        continue
                    newly.append(st)
            return newly

        pipe.run_pool(states, pool_stats, admit=admit)
        done_s = time.perf_counter()
        for tk in members:
            if tk.done_s is None:
                self._finalize(tk, wt.num_vertices, done_s)
            # pool-wide counters land once the pool's totals are known
            # (the stats object is shared with the ticket's TCQResult)
            tk.result.stats.absorb_pool(pool_stats,
                                        window_edges=wt.window_edges,
                                        batch_size=len(members))
        self._inflight = []
        # drop window TELs / pair tables of epochs no ticket pins anymore
        self.engine.retire_epochs({t.epoch for t in self._pending})
        fresh, self._fresh = self._fresh, []
        self.pool_log.append({
            "epoch": epoch, "window": (pool_lo, pool_hi),
            "members": len(members), "wave": wave,
            "admitted_midflight": pool_stats.admissions,
            "window_edges": wt.window_edges,
            "device_steps": pool_stats.device_steps,
            "occupancy": pool_stats.occupancy,
            "timeouts": sum(tk.status == "timeout" for tk in members),
            "cancelled": sum(tk.status == "cancelled" for tk in members),
            "cache_hits": sum(tk.result.stats.cells_cached
                              for tk in members),
            "backend": getattr(wt.step_fn, "backend", "?"),
            "wall_s": done_s - t0,
        })
        if pool_stats.shard_occupancy is not None:
            self.pool_log[-1]["shard_occupancy"] = \
                pool_stats.shard_occupancy
            self.pool_log[-1]["collective_bytes"] = \
                pool_stats.collective_bytes
        return members + fresh

    def run_until_idle(self, poll: Optional[Callable] = None
                       ) -> List[TCQTicket]:
        """Pump until no work is pending and ``poll`` (if any) stops
        producing new arrivals; returns every ticket resolved along the
        way (mid-flight admissions and resolved-at-submit empty windows
        included)."""
        served: List[TCQTicket] = []
        while True:
            out = self.pump(poll)
            served.extend(out)
            if not out and not self._pending:
                return served

    # ------------------------------------------------------------ prewarming
    def prewarm(self, max_windows: int = 1) -> int:
        """Speculatively peel the hottest request windows into the core
        cache while the service is idle.

        The arrival histogram (every submitted ``(k, h, ts, te)``) ranks
        windows by observed demand; the hottest whose schedule is not
        already fully cached at the *current* epoch are peeled through
        ``engine.query`` (wave mode), which inserts every cell on retire.
        Drivers call this from their idle branch (``launch.serve``'s
        open-loop driver does, between arrival gaps) so recurring traffic
        lands on a warm cache after ingest invalidation.  No-op when
        caching is off or work is pending (serving always wins the
        device).  Returns the number of windows peeled.
        """
        if self.engine.core_cache is None or self._pending:
            return 0
        peeled = 0
        for (k, h, ts, te), _ in sorted(self._hist.items(),
                                        key=lambda kv: (-kv[1], kv[0])):
            if peeled >= int(max_windows):
                break
            uts = self.engine.graph.unique_ts
            uts = uts[(uts >= ts) & (uts <= te)].astype(np.int64)
            if uts.size == 0:
                continue
            probe = QueryState(uts, k, h, True, QueryStats(),
                               cache=self.engine._cache_view(k, h))
            probe.resolve_cached()
            if probe.done:
                continue                    # already fully cached
            self.engine.query(k, int(ts), int(te), h=h, mode="wave",
                              wave=self.wave, depth=self.depth)
            self._prewarmed += 1
            peeled += 1
        return peeled

    @property
    def stats(self) -> Dict:
        """Service observability: engine cache counters (window-TEL LRU +
        TTI core cache, see ``TCQEngine.stats``) plus queue/prewarm
        gauges."""
        out = self.engine.stats()
        out["pending"] = len(self._pending)
        out["completed"] = len(self.completed)
        out["prewarmed"] = self._prewarmed
        if self.wal is not None:
            out["wal"] = self.wal.stats()
        return out

    # ------------------------------------------------------- crash recovery
    def snapshot(self) -> Dict:
        """Serializable service state: engine epoch, every epoch snapshot
        still pinned by a queued ticket, and the queued tickets themselves
        (deadlines stored as *remaining* seconds — wall-clock restarts).

        Pools run synchronously inside :meth:`pump`, so between pumps the
        queue is the complete in-flight set; a snapshot taken from a
        mid-pool ``poll``/admit hook additionally records the live pool's
        unresolved members (``_inflight``) as queued again — on restore
        they re-run from scratch, which is bit-identical because results
        are deterministic in (k, h, window, pinned epoch).  Restoring a
        snapshot and draining it therefore yields the same results as
        never having stopped (resolved tickets are the driver's to
        persist — they are not part of service state).
        """
        now = time.perf_counter()
        live = [tk for tk in self._inflight if not tk.done]
        graphs: Dict[int, Dict] = {self.engine.epoch:
                                   self.engine.graph.state_dict()}
        for tk in list(self._pending) + live:
            if tk.epoch not in graphs:
                graphs[tk.epoch] = tk.graph.state_dict()
        snap = {
            "version": 1,
            "epoch": int(self.engine.epoch),
            "next_id": int(self._next_id),
            "wave": self.wave,
            "depth": self.depth,
            "cluster_gap": self.cluster_gap,
            "graphs": graphs,
            "tickets": [{
                "id": tk.id, "k": tk.k, "h": tk.h,
                "ts": tk.ts, "te": tk.te,
                "epoch": tk.epoch, "priority": tk.priority,
                "deadline_rem_s": (None if tk.deadline is None
                                   else tk.deadline - now),
            } for tk in list(self._pending) + live],
        }
        if self.engine.core_cache is not None:
            # additive field (format stays version 1): a restoring service
            # without a cache simply drops it
            snap["cache"] = self.engine.core_cache.state_dict()
        return snap

    @classmethod
    def restore(cls, snap: Dict, **kwargs) -> "TCQService":
        """Rebuild a service from :meth:`snapshot`: replays the pinned
        epoch snapshots oldest-first (re-keying the engine to the original
        epoch numbers) and re-admits every queued ticket under its
        original id, epoch pin, priority and remaining deadline."""
        if int(snap.get("version", -1)) != 1:
            raise ValueError(f"unknown snapshot version: "
                             f"{snap.get('version')!r}")
        graphs = {int(e): TemporalGraph.from_state(s)
                  for e, s in snap["graphs"].items()}
        epochs = sorted(graphs)
        kwargs.setdefault("wave", snap["wave"])
        kwargs.setdefault("depth", int(snap["depth"]))
        kwargs.setdefault("cluster_gap", int(snap["cluster_gap"]))
        svc = cls(graphs[epochs[0]], **kwargs)
        svc.engine.rebase_epoch(epochs[0])
        for e in epochs[1:]:
            svc.engine.update_graph(graphs[e])
            svc.engine.rebase_epoch(e)
        now = time.perf_counter()
        for rec in snap["tickets"]:
            ep = int(rec["epoch"])
            g = graphs[ep]
            uts = g.unique_ts
            uts = uts[(uts >= int(rec["ts"])) & (uts <= int(rec["te"]))]
            rem = rec.get("deadline_rem_s")
            svc._pending.append(TCQTicket(
                id=int(rec["id"]), k=int(rec["k"]), h=int(rec["h"]),
                ts=int(rec["ts"]), te=int(rec["te"]), epoch=ep, graph=g,
                uts=uts.astype(np.int64), submit_s=now,
                priority=int(rec.get("priority", 0)),
                deadline=None if rem is None else now + float(rem)))
        svc._next_id = int(snap["next_id"])
        cache_state = snap.get("cache")
        if cache_state is not None and svc.engine.core_cache is not None:
            # persisted entries carry the pre-crash epoch numbering, which
            # the rebase replay above restored — keys line up exactly
            svc.engine.core_cache.load_state(cache_state)
        return svc

    def save_snapshot(self, path_or_file, *,
                      wal_seq: Optional[int] = None) -> None:
        """Persist :meth:`snapshot` as a single ``.npz`` (graph arrays +
        a JSON metadata record) — no pickle, loadable anywhere.

        The write is *atomic and self-verifying*: file-path targets go
        through a sibling ``.tmp`` + ``os.replace`` (a crash mid-save
        leaves any previous snapshot at that path untouched), and a
        whole-file CRC32 is embedded in the metadata record so
        :meth:`load_snapshot` / :meth:`recover` detect a damaged file
        instead of restoring from it.  ``wal_seq`` stamps the journal
        segment this snapshot seals (set by :meth:`checkpoint`)."""
        snap = self.snapshot()
        if wal_seq is not None:
            snap["wal_seq"] = int(wal_seq)
        arrays = {}
        for e, sd in snap.pop("graphs").items():
            for name, arr in sd.items():
                arrays[f"g{int(e)}__{name}"] = np.asarray(arr)
        for name, arr in snap.pop("cache", {}).items():
            arrays[f"cache__{name}"] = np.asarray(arr)
        walmod.write_snapshot_atomic(path_or_file, snap, arrays)

    @staticmethod
    def _parse_snapshot_file(path_or_file) -> Dict:
        """Read + checksum-verify one snapshot file back into the
        :meth:`snapshot` dict form (raises
        :class:`~repro.core.wal.SnapshotCorruption` on damage)."""
        snap, flat = walmod.read_snapshot(path_or_file)
        graphs: Dict[int, Dict] = {}
        cache: Dict[str, np.ndarray] = {}
        for key, arr in flat.items():
            tag, name = key.split("__", 1)
            if tag == "cache":
                cache[name] = arr
            else:
                graphs.setdefault(int(tag[1:]), {})[name] = arr
        snap["graphs"] = graphs
        if cache:
            snap["cache"] = cache
        return snap

    @classmethod
    def load_snapshot(cls, path_or_file, **kwargs) -> "TCQService":
        """Inverse of :meth:`save_snapshot` (checksum-verified)."""
        return cls.restore(cls._parse_snapshot_file(path_or_file),
                           **kwargs)

    # ------------------------------------------------------------ durability
    def _write_snapshot_file(self, seq: int) -> str:
        path = walmod.snapshot_path(self.wal.dir, seq)
        self.save_snapshot(path, wal_seq=seq)
        return path

    def checkpoint(self) -> Dict:
        """Durable checkpoint: seal the active journal segment, persist
        the current service state under the *new* segment's sequence
        number, then garbage-collect history older than the oldest
        retained checkpoint.

        Crash-ordering: a crash after the rotation but before the
        snapshot lands simply means recovery uses the previous snapshot
        and replays one segment more; a crash mid-snapshot-write leaves
        only a ``.tmp`` (swept by GC).  Retaining
        ``retained_checkpoints`` (default 2) snapshots — and every
        segment at or above the *oldest* retained one — makes the
        corrupt-newest-snapshot fallback lossless: the older snapshot's
        whole tail is still on disk.
        """
        if self.wal is None:
            raise walmod.WALError("checkpoint() needs a wal_dir")
        t0 = time.perf_counter()
        seq = self.wal.rotate()
        path = self._write_snapshot_file(seq)
        snaps = walmod.list_snapshots(self.wal.dir)
        keep = [s for s, _ in snaps][-max(1, int(self.retained_checkpoints)):]
        removed = self.wal.gc(keep[0])
        return {"path": path, "wal_seq": seq, "gc_removed": len(removed),
                "checkpoint_s": time.perf_counter() - t0}

    @classmethod
    def recover(cls, wal_dir: str, *, fsync: str = "batch",
                **kwargs) -> "TCQService":
        """Point-in-time crash recovery: newest valid snapshot + journal
        tail replay.

        Walks the directory's snapshots newest-first, skipping any that
        fail their checksum or parse (satellite contract: fall back, do
        not die mid-recovery), restores the first valid one, then
        replays every sealed journal segment at or after its ``wal_seq``
        through the real :meth:`submit` / ``add_edges`` /
        :meth:`cancel` paths — so the recovered queue, epoch numbering
        and pinned snapshots are exactly what an uninterrupted run would
        hold, and a subsequent drain is bit-identical.  A torn or
        corrupted record ends the replay at the last acknowledged
        operation (it is detected via CRC, reported in
        ``recovery_report["tail_events"]``, and physically truncated —
        never silently replayed).  Replay *verifies* as it goes: every
        re-ingested graph must match its record's fingerprint/counts and
        every re-admitted ticket its recorded id, else
        :class:`~repro.core.wal.WALReplayError`.

        The returned service has a fresh active segment and journals new
        mutations immediately; ``recovery_report`` carries the snapshot
        used, snapshots skipped, records replayed, tail events, and
        wall-clock recovery time (the drill's curve datum).
        """
        t0 = time.perf_counter()
        snaps = walmod.list_snapshots(wal_dir)
        if not snaps:
            raise walmod.WALError(f"no snapshot in {wal_dir!r} — nothing "
                                  "to recover (genesis missing?)")
        svc = None
        skipped = []
        kwargs.pop("wal", None)         # the journal is attached after
        kwargs.pop("wal_dir", None)     # replay, never during restore
        for seq, path in reversed(snaps):
            try:
                snap = cls._parse_snapshot_file(path)
                svc = cls.restore(snap, **kwargs)
                snap_seq, snap_path = seq, path
                break
            except (walmod.SnapshotCorruption, ValueError, KeyError) as e:
                skipped.append({"path": path, "error": repr(e)})
        if svc is None:
            raise walmod.WALError(
                f"every snapshot in {wal_dir!r} is corrupt: {skipped}")
        from_seq = int(snap.get("wal_seq", snap_seq))
        wal = walmod.WriteAheadLog(wal_dir, fsync=fsync)
        svc._replaying = True
        replayed = 0
        try:
            for rec in wal.replay(from_seq):
                svc._replay_record(rec)
                replayed += 1
        finally:
            svc._replaying = False
        svc.wal = wal
        svc.recovery_report = {
            "snapshot": snap_path,
            "snapshot_seq": int(snap_seq),
            "snapshots_skipped": skipped,
            "wal_records": replayed,
            "tail_events": list(wal.tail_events),
            "pending_after": len(svc._pending),
            "epoch_after": int(svc.epoch),
            "recover_s": time.perf_counter() - t0,
        }
        return svc

    def _replay_record(self, rec) -> None:
        """Apply one journal record through the live mutation paths."""
        kind, meta = rec.kind, rec.meta
        if kind == "submit":
            req = {"k": meta["k"], "h": meta["h"], "ts": meta["ts"],
                   "te": meta["te"], "priority": meta["priority"]}
            if meta.get("deadline_s") is not None:
                req["deadline_s"] = meta["deadline_s"]
            tk = self.submit(req)
            if tk.id != int(meta["id"]):
                raise walmod.WALReplayError(
                    f"replayed admission got id {tk.id}, journal "
                    f"recorded {meta['id']} — admission history is "
                    "incomplete or reordered")
        elif kind == "cancel":
            want = int(meta["id"])
            for tk in list(self._pending):
                if tk.id == want:
                    self.cancel(tk, status=meta["status"])
                    break
            # absent ids resolved before ever queueing (empty windows) —
            # the original cancel was a no-op on service state too
        elif kind == "edges":
            g = self.engine.graph.add_edges(
                rec.arrays["u"], rec.arrays["v"], rec.arrays["t"])
            self._check_lineage(g, meta)
            self.engine.update_graph(g)
        elif kind == "install":
            g = TemporalGraph.from_state(rec.arrays)
            self._check_lineage(g, meta)
            self.engine.update_graph(g)
        else:
            raise walmod.WALReplayError(f"unknown journal record kind "
                                        f"{kind!r}")

    @staticmethod
    def _check_lineage(g: TemporalGraph, meta: Dict) -> None:
        """Lineage check: the replayed graph must be byte-identical to
        the one the journal acknowledged (``uid`` lineage is
        process-local, so identity across restarts rests on the
        canonical-array fingerprint)."""
        got = {"graph_epoch": int(g.epoch),
               "num_vertices": int(g.num_vertices),
               "fingerprint": g.fingerprint()}
        if "num_edges" in meta:
            got["num_edges"] = g.num_edges
            got["num_pairs"] = g.num_pairs
        want = {k: meta[k] for k in got}
        if got != want:
            raise walmod.WALReplayError(
                f"replayed graph diverged from journal: got {got}, "
                f"recorded {want}")
