"""Temporal graph generators.

The paper evaluates on KONECT/SNAP datasets (Youtube, DBLP, Flickr,
CollegeMsg, email-Eu-core, sx-mathoverflow, sx-stackoverflow).  Those are not
redistributable inside this offline container, so benchmarks use generators
matched to their published shape statistics (|V|, |E|, time span, burstiness);
`load_snap_edges` ingests the real files when present.
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import TemporalGraph


def erdos_temporal(num_vertices: int, num_edges: int, time_span: int,
                   seed: int = 0) -> TemporalGraph:
    """Uniform random endpoints and timestamps — the adversarial case for
    pruning (few repeated cores)."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, num_vertices, num_edges)
    v = rng.integers(0, num_vertices, num_edges)
    t = rng.integers(1, time_span + 1, num_edges)
    return TemporalGraph.from_edges(u, v, t, num_vertices)


def powerlaw_temporal(num_vertices: int, num_edges: int, time_span: int,
                      alpha: float = 1.5, burst_periods: int = 6,
                      burst_frac: float = 0.5, seed: int = 0) -> TemporalGraph:
    """Skewed degrees + bursty timestamps — the social-network-like regime
    the paper's datasets live in (communities emerge in bursts)."""
    rng = np.random.default_rng(seed)
    # zipf-ish vertex popularity
    w = (np.arange(1, num_vertices + 1, dtype=np.float64)) ** (-alpha)
    w /= w.sum()
    u = rng.choice(num_vertices, size=num_edges, p=w)
    v = rng.choice(num_vertices, size=num_edges, p=w)
    # timestamps: uniform background + bursts
    n_burst = int(num_edges * burst_frac)
    t_bg = rng.integers(1, time_span + 1, num_edges - n_burst)
    centers = rng.integers(1, time_span + 1, burst_periods)
    which = rng.integers(0, burst_periods, n_burst)
    width = max(1, time_span // (burst_periods * 8))
    t_b = centers[which] + rng.integers(-width, width + 1, n_burst)
    t = np.clip(np.concatenate([t_bg, t_b]), 1, time_span)
    return TemporalGraph.from_edges(u, v, t, num_vertices)


def planted_cores(num_vertices: int = 64, k: int = 3, n_cliques: int = 4,
                  clique_size: int = 6, time_span: int = 40,
                  noise_edges: int = 120, seed: int = 0) -> TemporalGraph:
    """Graphs with known dense pockets at known times — sharp test cases for
    TTI pruning (many identical cores across subintervals)."""
    rng = np.random.default_rng(seed)
    us, vs, ts = [], [], []
    for c in range(n_cliques):
        verts = rng.choice(num_vertices, clique_size, replace=False)
        t0 = rng.integers(1, max(2, time_span - 4))
        for i in range(clique_size):
            for j in range(i + 1, clique_size):
                us.append(verts[i]); vs.append(verts[j])
                ts.append(int(t0 + rng.integers(0, 4)))
    u = rng.integers(0, num_vertices, noise_edges)
    v = rng.integers(0, num_vertices, noise_edges)
    t = rng.integers(1, time_span + 1, noise_edges)
    us = np.concatenate([np.array(us, dtype=np.int64), u])
    vs = np.concatenate([np.array(vs, dtype=np.int64), v])
    ts = np.concatenate([np.array(ts, dtype=np.int64), t])
    return TemporalGraph.from_edges(us, vs, ts, num_vertices)


def paper_style_example() -> TemporalGraph:
    """A small hand-built graph in the spirit of the paper's Figure 1:
    9 vertices, timestamps 1..8, two small bursty 2-cores that later merge
    into a larger one.  (The exact Figure 1 edge list is not recoverable from
    the text; tests validate against the brute-force oracle, and
    examples/quickstart.py walks this graph.)"""
    edges = [
        # an early triangle core around t=2..3 (v1,v2,v3)
        (1, 2, 2), (2, 3, 2), (1, 3, 3), (1, 2, 3),
        # a second burst at t=5..6 (v5,v6,v7) + bridge via v5
        (5, 6, 5), (6, 7, 5), (5, 7, 6), (5, 6, 6),
        # the merge: v3-v5, v4 joins everyone around t=6..8
        (3, 5, 6), (3, 4, 7), (4, 5, 7), (3, 4, 8), (4, 5, 8), (3, 5, 8),
        # background noise
        (0, 8, 1), (0, 1, 4), (7, 8, 4), (2, 6, 1), (1, 6, 8),
    ]
    return TemporalGraph.from_edge_list(edges, num_vertices=9)
