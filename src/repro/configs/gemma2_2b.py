"""Gemma-2 2B [arXiv:2408.00118] — alternating local(4096)/global layers,
attention + final-logit softcaps, pre+post RMSNorms, head_dim=256."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab=256_000,
    act="gelu", glu=True, pos="rope", embed_scale=True, post_norms=True,
    attn_softcap=50.0, logit_softcap=30.0,
    local_global_pattern=2, window=4096,
    tie_embeddings=True,
    max_seq=32_768,
    notes="alternating global layers keep it quadratic => long_500k skipped",
)
