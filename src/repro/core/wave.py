"""Wave-native batched TCD: Q query cells peeled in lockstep, kernel-ready.

`tcd_batch` (tcd.py) vmaps the scalar path; this module lays the data out
the way the MXU wants it — values [E, Q] / [2P, Q] — so the two segment
reductions become banded one-hot matmuls (the Pallas segdeg kernel), and
the whole wave shares one fixpoint loop.  The edge-activity / degree
split lets callers carry edge activity through the fixpoint loop and
skip the post-loop edge pass.  This is also the single-shard block of
the distributed engine (distributed.py wraps it in shard_map with a
cross-shard degree combine).

The device step itself — :func:`wave_step` (peel + TTI + stats + uint32
bitmask pack in one program) — lives here too, with two lowerings behind
one dispatcher, :func:`make_wave_step_fn`:

  * **fused Pallas** (``kernels/wave_peel``): the entire fixpoint loop
    runs on-chip per W-tile — no [W, E] HBM round-trips between
    iterations (compiled on TPU, interpret mode for CPU gates);
  * **XLA composite** (this module's ``peel_to_fixpoint`` chain): the
    portable fallback, also used when a TEL's VMEM working set exceeds
    the kernel budget.

Both lowerings are bit-identical (seeded fuzz gate in
tests/test_kernels.py); ``engine.WavePipeline``, :func:`tcd_wave` and
the distributed engine's single-shard block all route through the
dispatcher, so one kernel serves the single-query, batched and sharded
engines.
"""

from __future__ import annotations

import dataclasses
import functools
import weakref
from collections import OrderedDict
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.graph import DeviceTEL, TemporalGraph

_I32_MAX = jnp.iinfo(jnp.int32).max
_I32_MIN = jnp.iinfo(jnp.int32).min


class WaveResult(NamedTuple):
    alive: jnp.ndarray    # [Q, V]
    tti_lo: jnp.ndarray   # [Q]
    tti_hi: jnp.ndarray   # [Q]
    n_edges: jnp.ndarray  # [Q]
    n_verts: jnp.ndarray  # [Q]
    iters: jnp.ndarray    # scalar: fixpoint iterations of the wave


# ------------------------------------------------------- segsum closures
# (id(graph), epoch, use_kernel, interpret) -> (weakref(graph), closures).
# The band analysis (np.sort over 2P half-pairs + the kernel's k_max pass)
# used to rerun on every engine/bench construction for the same snapshot;
# epochs are immutable, so it is cacheable.  Keyed on the graph's
# process-unique ``uid`` — unlike ``id()``, never reused after GC, so a
# fresh graph allocated at a dead graph's address cannot inherit its
# closures.
_SEGSUM_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_SEGSUM_CACHE_MAX = 16


def make_segsum_fns(graph: TemporalGraph, *, use_kernel: Optional[bool] = None,
                    interpret: Optional[bool] = None):
    """(edges->pairs, halfpairs->vertices) segment-sum closures for a graph.

    use_kernel=True routes through the Pallas banded kernel (interpret mode
    on CPU); False uses jax.ops.segment_sum (XLA scatter path); None (the
    default) auto-dispatches — compiled Pallas on TPU, XLA elsewhere.  The
    band analysis (k_max) runs once per ``(graph, epoch)`` and is cached
    (graphs are immutable snapshots; appends bump ``epoch``).
    """
    from repro.kernels.segdeg.ops import make_banded_segsum, on_tpu

    if use_kernel is None:
        use_kernel = on_tpu()
    key = (graph.uid, graph.epoch, bool(use_kernel), interpret)
    hit = _SEGSUM_CACHE.get(key)
    if hit is not None:
        _SEGSUM_CACHE.move_to_end(key)
        return hit[1]
    tel_hp_src = np.sort(np.concatenate([graph.pair_u, graph.pair_v]))
    seg_pair = make_banded_segsum(graph.pair_id, graph.num_pairs,
                                  use_kernel=use_kernel, interpret=interpret)
    seg_vert = make_banded_segsum(tel_hp_src, graph.num_vertices,
                                  use_kernel=use_kernel, interpret=interpret)
    fns = (seg_pair, seg_vert)
    # identity lives entirely in the uid key; the weakref is kept only so
    # the entry does not extend the snapshot's lifetime
    _SEGSUM_CACHE[key] = (weakref.ref(graph), fns)
    while len(_SEGSUM_CACHE) > _SEGSUM_CACHE_MAX:
        _SEGSUM_CACHE.popitem(last=False)
    return fns


def wave_edge_activity(tel: DeviceTEL, alive: jnp.ndarray, ts, te
                       ) -> jnp.ndarray:
    """alive: [Q, V]; ts/te: [Q].  Returns [Q, E] bool edge activity."""
    win = (tel.t[None, :] >= ts[:, None]) & (tel.t[None, :] <= te[:, None])
    return win & alive[:, tel.src] & alive[:, tel.dst]


def wave_degrees_from_ea(tel: DeviceTEL, ea: jnp.ndarray, h,
                         *, num_vertices: int, seg_pair: Callable,
                         seg_vert: Callable) -> jnp.ndarray:
    """ea: [Q, E] edge activity; h: scalar or per-lane [Q].
    Returns [Q, V] int32 degrees."""
    paircnt = seg_pair(ea.T.astype(jnp.float32), tel.pair_id)  # [P, Q]
    pairact = (paircnt >= h).astype(jnp.float32)   # h broadcasts over lanes
    contrib = pairact[tel.hp_pair, :]                          # [2P, Q]
    deg = seg_vert(contrib, tel.hp_src)                        # [V, Q]
    return deg.T.astype(jnp.int32)


def wave_degrees(tel: DeviceTEL, alive: jnp.ndarray, ts, te, h,
                 *, num_vertices: int, seg_pair: Callable, seg_vert: Callable
                 ) -> jnp.ndarray:
    """alive: [Q, V]; ts/te: [Q].  Returns [Q, V] int32 degrees."""
    ea = wave_edge_activity(tel, alive, ts, te)
    return wave_degrees_from_ea(tel, ea, h, num_vertices=num_vertices,
                                seg_pair=seg_pair, seg_vert=seg_vert)


def peel_to_fixpoint(tel: DeviceTEL, alive: jnp.ndarray, ts, te, k, h,
                     *, num_vertices: int, seg_pair, seg_vert,
                     max_iters: int = 0):
    """Shared batched peel loop -> (alive, ea, iters); trace-time building
    block for `tcd_wave` and the composite ``wave_step`` lowering.

    k and h may be scalars (one threshold for the whole wave) or per-lane
    [Q] vectors — the multi-tenant scheduler packs cells from queries with
    different (k, h) into one wave, so the survivor test broadcasts the
    thresholds per lane.

    ea rides in the carry (as in tcd.tcd): the final iteration observed
    new == cur, so the carried ea is exactly the fixpoint's edge activity
    and callers skip the post-loop edge pass.
    """
    q = alive.shape[0]
    k_lane = jnp.broadcast_to(jnp.asarray(k, jnp.int32), (q,))
    h_lane = jnp.broadcast_to(jnp.asarray(h, jnp.int32), (q,))
    ts = jnp.broadcast_to(jnp.asarray(ts, jnp.int32), (q,))
    te = jnp.broadcast_to(jnp.asarray(te, jnp.int32), (q,))
    # the [Q, E] window mask depends only on (ts, te), never on alive —
    # computed once, reused by every fixpoint iteration (it used to be
    # rebuilt inside the loop body on this path)
    win = (tel.t[None, :] >= ts[:, None]) & (tel.t[None, :] <= te[:, None])

    def edge_activity(cur):
        return win & cur[:, tel.src] & cur[:, tel.dst]

    def cond(state):
        _, _, changed, it = state
        more = changed
        if max_iters:
            more = more & (it < max_iters)
        return more

    def body(state):
        cur, _, _, it = state
        ea = edge_activity(cur)
        deg = wave_degrees_from_ea(tel, ea, h_lane,
                                   num_vertices=num_vertices,
                                   seg_pair=seg_pair, seg_vert=seg_vert)
        new = cur & (deg >= k_lane[:, None])
        return new, ea, jnp.any(new != cur), it + 1

    ea0 = jnp.zeros((alive.shape[0], tel.t.shape[0]), dtype=bool)
    alive, ea, _, iters = lax.while_loop(
        cond, body, (alive, ea0, jnp.bool_(True), jnp.int32(0)))
    if max_iters:  # truncated peel may exit pre-fixpoint: ea would be stale
        ea = edge_activity(alive)
    return alive, ea, iters


# ------------------------------------------------------------ bitmask pack
def packed_width(num_vertices: int) -> int:
    """uint32 words per packed [V] vertex mask."""
    return max(1, -(-num_vertices // 32))


def _pack_u32(alive: jnp.ndarray, num_vertices: int) -> jnp.ndarray:
    """[..., V] bool -> [..., ceil(V/32)] uint32; vertex v = bit v%32 of
    word v//32 (LSB-first, matching np.unpackbits(bitorder="little"))."""
    w = packed_width(num_vertices)
    pad = w * 32 - num_vertices
    a = jnp.pad(alive, [(0, 0)] * (alive.ndim - 1) + [(0, pad)])
    a = a.reshape(a.shape[:-1] + (w, 32)).astype(jnp.uint32)
    return jnp.sum(a << jnp.arange(32, dtype=jnp.uint32), axis=-1,
                   dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("num_vertices",))
def pack_alive_u32(alive: jnp.ndarray, *, num_vertices: int) -> jnp.ndarray:
    """Standalone jitted pack (used by the distributed engine's packed
    result transfer; ``wave_step`` fuses the same computation inline)."""
    return _pack_u32(alive, num_vertices)


def unpack_alive_u32(packed: np.ndarray, num_vertices: int) -> np.ndarray:
    """Host-side inverse of :func:`pack_alive_u32` — one bulk unpackbits."""
    packed = np.ascontiguousarray(np.asarray(packed).astype("<u4",
                                                            copy=False))
    bits = np.unpackbits(packed.view(np.uint8), axis=-1, bitorder="little")
    return bits[..., :num_vertices].astype(bool)


# ------------------------------------------------------------- fused step
class StepResult(NamedTuple):
    alive: jnp.ndarray    # [W, V] bool — the persistent lane buffer
    packed: jnp.ndarray   # [W, ceil(V/32)] uint32 bitmask of `alive`
    tti_lo: jnp.ndarray   # [W] int32 (I32_MAX when lane core is empty)
    tti_hi: jnp.ndarray   # [W] int32 (I32_MIN when lane core is empty)
    n_edges: jnp.ndarray  # [W] int32
    iters: jnp.ndarray    # scalar int32 — shared fixpoint iterations


def _wave_step_impl(tel: DeviceTEL, alive: jnp.ndarray, ts, te, k, h,
                    *, num_vertices: int, seg_pair, seg_vert) -> StepResult:
    alive, ea, iters = peel_to_fixpoint(
        tel, alive, ts, te, k, h, num_vertices=num_vertices,
        seg_pair=seg_pair, seg_vert=seg_vert)
    n_edges = jnp.sum(ea, axis=1, dtype=jnp.int32)
    tti_lo = jnp.min(jnp.where(ea, tel.t[None, :], _I32_MAX), axis=1)
    tti_hi = jnp.max(jnp.where(ea, tel.t[None, :], _I32_MIN), axis=1)
    return StepResult(alive, _pack_u32(alive, num_vertices),
                      tti_lo, tti_hi, n_edges, iters)


#: XLA-composite device step: peel W lanes to the fixpoint + TTI + stats +
#: bitmask pack in one jitted program.  ``ts``/``te``/``k``/``h`` are
#: per-lane [W] vectors — every lane may carry a different query's window
#: and thresholds.  ``alive`` is donated — the lane buffer is peeled in
#: place and handed back as ``StepResult.alive``.
wave_step = functools.partial(
    jax.jit, static_argnames=("num_vertices", "seg_pair", "seg_vert"),
    donate_argnums=(1,))(_wave_step_impl)

# non-donating twin for callers that reuse their alive buffer across calls
# (tcd_wave, benches); same trace, separate jit cache
_wave_step_nodonate = functools.partial(
    jax.jit, static_argnames=("num_vertices", "seg_pair",
                              "seg_vert"))(_wave_step_impl)


def _make_xla_step(tel: DeviceTEL, num_vertices: int, *,
                   seg_pair=None, seg_vert=None, donate: bool = False):
    """The XLA-composite lowering as a ``make_wave_step_fn``-shaped
    closure (also the degradation ladder's middle rung)."""
    if seg_pair is None or seg_vert is None:
        from repro.kernels.segdeg.ref import banded_segsum_ref

        if seg_pair is None:
            seg_pair = functools.partial(banded_segsum_ref,
                                         num_segments=tel.num_pairs)
        if seg_vert is None:
            seg_vert = functools.partial(banded_segsum_ref,
                                         num_segments=num_vertices)
    inner = wave_step if donate else _wave_step_nodonate

    def step(alive, ts, te, k, h):
        return inner(tel, alive, ts, te, k, h, num_vertices=num_vertices,
                     seg_pair=seg_pair, seg_vert=seg_vert)

    step.backend = "xla"
    step.interpret = False
    return step


def make_oracle_step_fn(tel: DeviceTEL, num_vertices: int):
    """Serial numpy reference step — the degradation ladder's last rung
    and the divergence tripwire's ground truth.

    Pure host-side numpy over host copies of the (possibly capacity- or
    bucket-padded) TEL: no jit, no Pallas, no XLA — nothing left to
    degrade to.  Bit-identical to the composite on every ``StepResult``
    field including the shared iteration count: the loop mirrors the
    composite's ``lax.while_loop`` (body runs while any lane changed, the
    final iteration observes the fixpoint), the segment reductions mirror
    the scatter paths' sentinel-drop semantics (``pair_id == P`` and
    ``hp_src == V`` fall outside the bincount slice), and the bitmask
    pack is the same LSB-first uint32 layout.
    """
    t = np.asarray(tel.t)
    src = np.asarray(tel.src)
    dst = np.asarray(tel.dst)
    pair_id = np.asarray(tel.pair_id).astype(np.int64)
    hp_src = np.asarray(tel.hp_src).astype(np.int64)
    hp_pair = np.asarray(tel.hp_pair).astype(np.int64)
    p_cap = int(tel.pair_u.shape[0])
    v = int(num_vertices)
    pw = packed_width(v)

    def _lanes(x, w, dtype=np.int64):
        return np.broadcast_to(np.asarray(x), (w,)).astype(dtype)

    def step(alive, ts, te, k, h):
        cur = np.array(np.asarray(alive), dtype=bool)
        w = cur.shape[0]
        ts_l, te_l = _lanes(ts, w), _lanes(te, w)
        k_l, h_l = _lanes(k, w), _lanes(h, w)
        win = (t[None, :] >= ts_l[:, None]) & (t[None, :] <= te_l[:, None])
        it = 0
        while True:
            ea = win & cur[:, src] & cur[:, dst]
            it += 1
            new = np.empty_like(cur)
            for li in range(w):
                paircnt = np.bincount(pair_id[ea[li]],
                                      minlength=p_cap + 1)[:p_cap]
                contrib = (paircnt >= h_l[li])[hp_pair]
                # sentinel halfpairs (hp_src == V) fall outside the slice,
                # like the scatter reduction's out-of-range segment drop
                deg = np.bincount(hp_src[contrib], minlength=v + 1)[:v]
                new[li] = cur[li] & (deg >= k_l[li])
            if np.array_equal(new, cur):
                break
            cur = new
        n_edges = ea.sum(axis=1).astype(np.int32)
        tti_lo = np.full(w, _I32_MAX, np.int32)
        tti_hi = np.full(w, _I32_MIN, np.int32)
        for li in range(w):
            if n_edges[li]:
                t_act = t[ea[li]]
                tti_lo[li] = t_act.min()
                tti_hi[li] = t_act.max()
        pad = pw * 32 - v
        bits = np.pad(cur, [(0, 0), (0, pad)])
        packed = np.packbits(bits, axis=-1,
                             bitorder="little").view("<u4")
        return StepResult(jnp.asarray(cur), jnp.asarray(packed),
                          jnp.asarray(tti_lo), jnp.asarray(tti_hi),
                          jnp.asarray(n_edges), jnp.int32(it))

    step.backend = "oracle"
    step.interpret = False
    return step


# --------------------------------------------------- degradation ladder
@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs for the graceful-degradation ladder (pass as
    ``make_wave_step_fn(resilience=...)`` / ``TCQEngine(resilience=...)``).

    tripwire_every:
        Sample every Nth step call: recompute one random lane on the
        numpy oracle and compare bit-for-bit; a divergence quarantines
        the current rung and replays the call one rung down.  0 disables
        the tripwire (errors still demote).
    seed:
        Seeds the tripwire's lane sampling (determinism for the chaos
        harness).
    interpret / vmem_budget_bytes:
        Overrides for the Pallas rung's build (None = the dispatcher
        defaults).
    rung_wrapper:
        ``wrapper(name, step_fn) -> step_fn`` applied to each rung at
        build time — the fault-injection seam (``core/faultinject.py``).
    """

    tripwire_every: int = 64
    seed: int = 0
    interpret: Optional[bool] = None
    vmem_budget_bytes: Optional[int] = None
    rung_wrapper: Optional[Callable] = None


class DegradationLadder:
    """Graceful degradation across the step lowerings: fused Pallas ->
    XLA composite -> serial numpy oracle.

    Built like a step_fn, called like a step_fn.  Every rung is
    *non-donating*, so when a rung fails — a build/compile error, a
    raised fault, or a tripwire divergence — the same inputs replay on
    the next rung bit-identically: demotion is invisible in the results,
    it only shows up in ``events`` and latency.  A demoted rung is
    quarantined for this ladder's lifetime (ladders are pinned per
    ``(epoch, Ts, Te)`` window entry, so a quarantine lasts the epoch);
    an unavailable Pallas rung (VMEM budget, build failure) starts the
    ladder on the composite with the reason recorded.
    """

    def __init__(self, tel: DeviceTEL, num_vertices: int, *,
                 seg_pair=None, seg_vert=None,
                 use_kernel: bool = False,
                 interpret: Optional[bool] = None,
                 w_tile: int = 8,
                 config: Optional[ResilienceConfig] = None):
        self.config = config or ResilienceConfig()
        self.events = []            # [{rung, reason, detail, call}]
        self.calls = 0
        self.rung = 0
        self._rng = np.random.default_rng(self.config.seed)
        if self.config.interpret is not None:
            interpret = self.config.interpret
        rungs = []
        if use_kernel:
            from repro.kernels.wave_peel.ops import (DEFAULT_VMEM_BUDGET,
                                                     make_fused_wave_step)

            budget = (DEFAULT_VMEM_BUDGET
                      if self.config.vmem_budget_bytes is None
                      else int(self.config.vmem_budget_bytes))
            try:
                fused = make_fused_wave_step(tel, num_vertices,
                                             w_tile=w_tile,
                                             interpret=interpret,
                                             donate=False,
                                             vmem_budget_bytes=budget)
                if fused is None:
                    self._log("pallas", "vmem_budget",
                              f"budget={budget} bytes")
                else:
                    rungs.append(("pallas", fused))
            except Exception as e:                   # pragma: no cover
                self._log("pallas", "build_error", repr(e))
        rungs.append(("xla", _make_xla_step(tel, num_vertices,
                                            seg_pair=seg_pair,
                                            seg_vert=seg_vert,
                                            donate=False)))
        oracle = make_oracle_step_fn(tel, num_vertices)
        self._truth = oracle        # tripwire ground truth stays unwrapped
        rungs.append(("oracle", oracle))
        wrap = self.config.rung_wrapper
        if wrap is not None:
            rungs = [(name, wrap(name, fn) or fn) for name, fn in rungs]
        self.rungs = rungs

    def _log(self, rung: str, reason: str, detail: str = "") -> None:
        self.events.append({"rung": rung, "reason": reason,
                            "detail": detail, "call": self.calls})

    @property
    def backend(self) -> str:
        return self.rungs[self.rung][0]

    @property
    def interpret(self) -> bool:
        return bool(getattr(self.rungs[self.rung][1], "interpret", False))

    def _demote(self, name: str, reason: str, detail: str = "") -> None:
        self._log(name, reason, detail)
        self.rung += 1

    @staticmethod
    def _lane_slice(x, lane: int, w: int) -> np.ndarray:
        return np.broadcast_to(np.asarray(x), (w,))[lane:lane + 1]

    def _lane_check(self, res: StepResult, alive, ts, te, k, h) -> bool:
        """Sampled cross-check: one random lane recomputed on the oracle
        (lanes are mathematically independent, so a single-lane oracle
        run must match that lane of the wave exactly — except the shared
        iteration count, which is a max over lanes)."""
        w = int(res.alive.shape[0])
        lane = int(self._rng.integers(w))
        truth = self._truth(
            np.asarray(alive)[lane:lane + 1],
            self._lane_slice(ts, lane, w), self._lane_slice(te, lane, w),
            self._lane_slice(k, lane, w), self._lane_slice(h, lane, w))
        got = jax.device_get((res.alive[lane], res.packed[lane],
                              res.tti_lo[lane], res.tti_hi[lane],
                              res.n_edges[lane]))
        want = jax.device_get((truth.alive[0], truth.packed[0],
                               truth.tti_lo[0], truth.tti_hi[0],
                               truth.n_edges[0]))
        return all(np.array_equal(g, x) for g, x in zip(got, want))

    def __call__(self, alive, ts, te, k, h) -> StepResult:
        self.calls += 1
        every = self.config.tripwire_every
        check = bool(every) and self.calls % every == 0
        while True:
            name, fn = self.rungs[self.rung]
            last = self.rung == len(self.rungs) - 1
            try:
                res = fn(alive, ts, te, k, h)
            except Exception as e:
                if last:
                    raise
                self._demote(name, "error", repr(e))
                continue            # replay the same cells one rung down
            if check and not last and not self._lane_check(
                    res, alive, ts, te, k, h):
                self._demote(name, "divergence", f"call {self.calls}")
                continue            # quarantine + bit-identical replay
            return res


def make_wave_step_fn(tel: DeviceTEL, num_vertices: int, *,
                      seg_pair=None, seg_vert=None,
                      use_kernel: Optional[bool] = None,
                      interpret: Optional[bool] = None,
                      w_tile: int = 8, donate: bool = False,
                      vmem_budget_bytes: Optional[int] = None,
                      resilience: Optional[ResilienceConfig] = None):
    """Build the device step for one TEL: ``step(alive, ts, te, k, h) ->
    StepResult``, with ``.backend`` ("pallas" | "xla" | "oracle") and
    ``.interpret`` attributes.

    use_kernel=True routes through the fused Pallas peel-to-fixpoint
    kernel (interpret mode off-TPU unless ``interpret`` says otherwise);
    False through the XLA composite; None (default) auto-dispatches —
    compiled Pallas on TPU, XLA elsewhere.  A TEL whose VMEM working set
    exceeds the kernel budget falls back to the composite (the window
    truncation normally keeps E far below that).  ``donate=True`` donates
    the alive buffer (the pipeline's persistent lane slab); leave False
    when the caller reuses its buffer across calls.

    With ``resilience`` set, the returned step is a
    :class:`DegradationLadder` over the same lowerings (Pallas -> XLA ->
    numpy oracle) that demotes on build/VMEM failure, raised errors, or
    a sampled divergence tripwire and replays failed calls on the next
    rung bit-identically.  Ladder rungs never donate (``donate`` is
    ignored): a replay needs its inputs intact.

    The lowerings are bit-identical — alive, packed words, TTI lo/hi,
    edge counts and the iteration count all match exactly (seeded fuzz
    gates in tests/test_kernels.py and tests/test_resilience.py).
    """
    from repro.kernels.segdeg.ops import on_tpu

    if use_kernel is None:
        use_kernel = on_tpu()
    if resilience is not None:
        if resilience.vmem_budget_bytes is None and \
                vmem_budget_bytes is not None:
            resilience = dataclasses.replace(
                resilience, vmem_budget_bytes=int(vmem_budget_bytes))
        return DegradationLadder(tel, num_vertices, seg_pair=seg_pair,
                                 seg_vert=seg_vert, use_kernel=use_kernel,
                                 interpret=interpret, w_tile=w_tile,
                                 config=resilience)
    if use_kernel:
        from repro.kernels.wave_peel.ops import (DEFAULT_VMEM_BUDGET,
                                                 make_fused_wave_step)

        budget = (DEFAULT_VMEM_BUDGET if vmem_budget_bytes is None
                  else int(vmem_budget_bytes))
        fused = make_fused_wave_step(tel, num_vertices, w_tile=w_tile,
                                     interpret=interpret, donate=donate,
                                     vmem_budget_bytes=budget)
        if fused is not None:
            return fused
    return _make_xla_step(tel, num_vertices, seg_pair=seg_pair,
                          seg_vert=seg_vert, donate=donate)


def tcd_wave(tel: DeviceTEL, alive: jnp.ndarray, ts, te, k, h,
             *, num_vertices: int, seg_pair=None, seg_vert=None,
             max_iters: int = 0, step_fn=None) -> WaveResult:
    """Batched TCD to the fixpoint.  alive: [Q, V] warm-start supersets;
    k/h: scalars or per-lane [Q] vectors (mixed-threshold waves).

    Pass ``step_fn`` (from :func:`make_wave_step_fn`) to route through a
    prebuilt device step — the fused Pallas kernel on TPU; otherwise the
    jitted XLA composite runs against ``seg_pair``/``seg_vert``.
    """
    if step_fn is not None:
        if max_iters:
            raise ValueError(
                "step_fn peels to the fixpoint; max_iters is only "
                "supported on the composite path")
        r = step_fn(alive, ts, te, k, h)
        n_verts = jnp.sum(r.alive, axis=1, dtype=jnp.int32)
        return WaveResult(r.alive, r.tti_lo, r.tti_hi, r.n_edges,
                          n_verts, r.iters)
    return _tcd_wave_xla(tel, alive, ts, te, k, h,
                         num_vertices=num_vertices, seg_pair=seg_pair,
                         seg_vert=seg_vert, max_iters=max_iters)


@functools.partial(jax.jit, static_argnames=("num_vertices", "seg_pair",
                                             "seg_vert", "max_iters"))
def _tcd_wave_xla(tel: DeviceTEL, alive: jnp.ndarray, ts, te, k, h,
                  *, num_vertices: int, seg_pair, seg_vert,
                  max_iters: int = 0) -> WaveResult:
    alive, ea, iters = peel_to_fixpoint(
        tel, alive, ts, te, k, h, num_vertices=num_vertices,
        seg_pair=seg_pair, seg_vert=seg_vert, max_iters=max_iters)
    n_edges = jnp.sum(ea, axis=1, dtype=jnp.int32)
    tti_lo = jnp.min(jnp.where(ea, tel.t[None, :], _I32_MAX), axis=1)
    tti_hi = jnp.max(jnp.where(ea, tel.t[None, :], _I32_MIN), axis=1)
    n_verts = jnp.sum(alive, axis=1, dtype=jnp.int32)
    return WaveResult(alive, tti_lo, tti_hi, n_edges, n_verts, iters)
