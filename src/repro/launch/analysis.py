"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, in seconds:

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = sum over collective ops of operand_bytes * ring_factor
               / (links * ICI_BW)

``cost_analysis()`` on a GSPMD-partitioned executable reports *per-device*
flops/bytes (the partitioned module), so no further division by chip count
is applied; the methodology note in EXPERIMENTS.md records this.
Collective bytes are parsed from the post-SPMD HLO text; ring factors:
all-reduce 2(g-1)/g, all-gather/reduce-scatter/all-to-all (g-1)/g,
collective-permute 1.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link
ICI_LINKS = 2            # links per axis direction usable concurrently (2D torus)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_SZ_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    operand_bytes: int
    group_size: int
    line: str = ""

    @property
    def ring_factor(self) -> float:
        g = max(self.group_size, 1)
        if self.kind == "all-reduce":
            return 2.0 * (g - 1) / g
        if self.kind == "collective-permute":
            return 1.0
        return (g - 1) / g

    @property
    def wire_bytes(self) -> float:
        return self.operand_bytes * self.ring_factor


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    out: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.search(r"\b(" + "|".join(_COLL_KINDS) + r")(-start)?\(", s)
        if not m or "-done" in s.split("=")[0]:
            continue
        kind = m.group(1)
        # shapes: first match = output (LHS), the rest are operand types
        shapes = _SHAPE_RE.findall(s)
        if not shapes:
            continue
        paren = s[m.end():]
        operand_shapes = _SHAPE_RE.findall(paren)
        if not operand_shapes:  # tuple output form: use output as estimate
            operand_shapes = shapes[:1]
        ob = sum(_shape_bytes(d, dims) for d, dims in operand_shapes)
        gm = _GROUPS_RE.search(s)
        if gm:
            gsz = len([x for x in gm.group(1).split(",") if x.strip() != ""])
        else:
            gm2 = _GROUPS_SZ_RE.search(s)
            gsz = int(gm2.group(2)) if gm2 else 1
        out.append(CollectiveOp(kind, ob, gsz, s[:160]))
    return out


def collective_summary(ops: List[CollectiveOp]) -> Dict[str, Dict[str, float]]:
    summ: Dict[str, Dict[str, float]] = {}
    for op in ops:
        d = summ.setdefault(op.kind, {"count": 0, "operand_bytes": 0.0,
                                      "wire_bytes": 0.0})
        d["count"] += 1
        d["operand_bytes"] += op.operand_bytes
        d["wire_bytes"] += op.wire_bytes
    return summ


def roofline_terms(cost: Optional[dict], ops: List[CollectiveOp],
                   model_flops_per_device: float = 0.0) -> Dict[str, float]:
    cost = cost or {}
    flops = float(cost.get("flops", 0.0))
    # XLA:CPU reports bytes accessed via 'bytes accessed{}' keys
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    if not bytes_acc:
        bytes_acc = sum(float(v) for k, v in cost.items()
                        if k.startswith("bytes accessed"))
    wire = sum(op.wire_bytes for op in ops)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = wire / (ICI_LINKS * ICI_BW)
    dom = max((("compute", t_compute), ("memory", t_memory),
               ("collective", t_coll)), key=lambda kv: kv[1])[0]
    out = {
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "collective_wire_bytes_per_device": wire,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dom,
        "bound_step_time_s": max(t_compute, t_memory, t_coll),
    }
    if model_flops_per_device:
        out["model_flops_per_device"] = model_flops_per_device
        out["useful_compute_ratio"] = (
            model_flops_per_device / flops if flops else 0.0)
        peak_time = model_flops_per_device / PEAK_FLOPS
        out["roofline_fraction"] = (
            peak_time / out["bound_step_time_s"]
            if out["bound_step_time_s"] else 0.0)
    return out
