"""Write-ahead journal for the streaming TCQ service (durability tier).

PR 5's crash recovery is snapshot-only: everything between two
``save_snapshot`` calls — every ingested edge batch, every admitted or
cancelled ticket — is silently lost on a crash, and the snapshot itself
was written in place, so a crash *mid-save* corrupted the only recovery
point.  This module closes that gap with the standard database recipe,
adapted to the service's epoch-pinned snapshot model:

* **Append-only segments of checksummed records.**  Every mutation the
  service accepts (``add_edges`` batch, ticket admission, cancellation,
  external snapshot install) is encoded as one length-prefixed record —
  ``u32 payload_len | u32 crc32(payload) | payload`` — and appended to
  the active segment *before* the mutation is applied (write-ahead: a
  mutation is durable iff its record is).  Payloads are self-describing
  (JSON meta + raw little-endian array bytes), pickle-free.

* **Torn-tail tolerance.**  A crash can leave a half-written record at
  the tail (or bit rot can corrupt an older one).  Recovery verifies
  every record's CRC and *cuts* the log at the first bad record: the
  event is reported (``tail_events``), the surviving prefix is replayed,
  and the bad bytes are physically truncated so they can never be
  misread later.  A torn record is an operation that was never
  acknowledged — cutting it is correct, replaying garbage is not.

* **Segment rotation keyed to snapshot points.**  Segments and snapshots
  share one monotonically increasing sequence number.  A checkpoint
  seals the active segment (``rotate``), writes the snapshot under the
  *new* segment's sequence number, and garbage-collects segments older
  than the oldest retained snapshot.  Recovery therefore loads the
  newest valid snapshot ``snapshot-S`` and replays exactly the segments
  with ``seq >= S`` — the WAL tail.

* **fsync policy.**  ``always`` fsyncs every append (no acknowledged
  record can be lost to an OS crash), ``batch`` fsyncs on an explicit
  ``sync()`` / rotation (the service syncs at pump boundaries — bounded
  loss on power failure, cheap in the common case), ``off`` leaves
  flushing to the OS (process crashes still lose nothing, because the
  stream position is flushed; only a machine crash can).

The service-side half — journal hooks in ``submit``/``push_edges``/
``cancel``, atomic checkpoints, and ``TCQService.recover`` — lives in
``core/service.py``; this module knows nothing about tickets beyond
bytes.  Crash-point and torn-write *injection* lives in
``core/faultinject.py`` (``CrashingWAL``); the kill-anywhere drill that
gates bit-identical recovery at every injected point is
``benchmarks/bench_chaos.run_durability``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import struct
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

SEGMENT_MAGIC = b"TWAL"
SEGMENT_VERSION = 1
_SEG_HEADER = struct.Struct("<4sI")      # magic, version
_REC_HEADER = struct.Struct("<II")       # payload_len, crc32(payload)
_SEG_RE = re.compile(r"^wal-(\d{8})\.log$")
_SNAP_RE = re.compile(r"^snapshot-(\d{8})\.npz$")

FSYNC_POLICIES = ("always", "batch", "off")


class WALError(RuntimeError):
    """Unrecoverable WAL structure problem (bad header, unknown policy)."""


class WALReplayError(WALError):
    """A replayed record did not reproduce the state it promised
    (lineage fingerprint mismatch, id collision) — the log and the
    replay path disagree, which must fail loudly, never sort-of-recover."""


@dataclasses.dataclass(frozen=True)
class WALRecord:
    """One decoded journal record: a kind tag, JSON-able metadata, and
    named numpy arrays (dtype/shape round-trip exactly)."""

    kind: str
    meta: Dict
    arrays: Dict[str, np.ndarray]


def encode_record(kind: str, meta: Optional[Dict] = None,
                  arrays: Optional[Dict[str, np.ndarray]] = None) -> bytes:
    """Frame one record: header JSON (kind, meta, array specs) + raw
    array bytes, length-prefixed and CRC32-checksummed."""
    metas = dict(meta or {})
    specs = []
    blobs = []
    for name, arr in (arrays or {}).items():
        a = np.ascontiguousarray(arr)
        # canonical little-endian byte order: segments written on one
        # host must replay on any other
        a = a.astype(a.dtype.newbyteorder("<"), copy=False)
        specs.append([name, a.dtype.str, list(a.shape)])
        blobs.append(a.tobytes())
    head = json.dumps({"kind": kind, "meta": metas, "arrays": specs},
                      sort_keys=True).encode()
    payload = struct.pack("<I", len(head)) + head + b"".join(blobs)
    return _REC_HEADER.pack(len(payload), zlib.crc32(payload)) + payload


def decode_payload(payload: bytes) -> WALRecord:
    (head_len,) = struct.unpack_from("<I", payload, 0)
    head = json.loads(payload[4:4 + head_len].decode())
    arrays: Dict[str, np.ndarray] = {}
    off = 4 + head_len
    for name, dtype, shape in head["arrays"]:
        dt = np.dtype(dtype)
        n = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
        arrays[name] = np.frombuffer(
            payload[off:off + n], dtype=dt).reshape(shape).copy()
        off += n
    return WALRecord(head["kind"], head["meta"], arrays)


def segment_path(wal_dir: str, seq: int) -> str:
    return os.path.join(wal_dir, f"wal-{int(seq):08d}.log")


def snapshot_path(wal_dir: str, seq: int) -> str:
    return os.path.join(wal_dir, f"snapshot-{int(seq):08d}.npz")


def list_segments(wal_dir: str) -> List[Tuple[int, str]]:
    """(seq, path) for every segment file, ascending ([] if the
    directory does not exist yet)."""
    if not os.path.isdir(wal_dir):
        return []
    out = []
    for name in os.listdir(wal_dir):
        m = _SEG_RE.match(name)
        if m:
            out.append((int(m.group(1)), os.path.join(wal_dir, name)))
    return sorted(out)


def list_snapshots(wal_dir: str) -> List[Tuple[int, str]]:
    """(seq, path) for every snapshot file, ascending ([] if the
    directory does not exist yet)."""
    if not os.path.isdir(wal_dir):
        return []
    out = []
    for name in os.listdir(wal_dir):
        m = _SNAP_RE.match(name)
        if m:
            out.append((int(m.group(1)), os.path.join(wal_dir, name)))
    return sorted(out)


def read_segment(path: str) -> Tuple[List[WALRecord], Optional[Dict], int]:
    """Decode one segment: ``(records, tail_event, valid_bytes)``.

    ``tail_event`` is None for a clean segment, else a dict describing
    the first bad record (``reason`` in {"torn", "corrupt", "bad_header"})
    — everything at and after it is excluded from ``records``.
    ``valid_bytes`` is the offset of the last byte that parsed cleanly
    (the truncation point for :func:`cut_segment`).
    """
    with open(path, "rb") as f:
        data = f.read()
    if len(data) < _SEG_HEADER.size:
        return [], {"reason": "bad_header", "offset": 0,
                    "detail": f"{len(data)} bytes, no segment header"}, 0
    magic, version = _SEG_HEADER.unpack_from(data, 0)
    if magic != SEGMENT_MAGIC or version != SEGMENT_VERSION:
        return [], {"reason": "bad_header", "offset": 0,
                    "detail": f"magic={magic!r} version={version}"}, 0
    records: List[WALRecord] = []
    off = _SEG_HEADER.size
    while off < len(data):
        if off + _REC_HEADER.size > len(data):
            return records, {"reason": "torn", "offset": off,
                             "detail": "partial record header"}, off
        length, crc = _REC_HEADER.unpack_from(data, off)
        start = off + _REC_HEADER.size
        payload = data[start:start + length]
        if len(payload) < length:
            return records, {
                "reason": "torn", "offset": off,
                "detail": f"record wants {length} payload bytes, "
                          f"{len(payload)} on disk"}, off
        if zlib.crc32(payload) != crc:
            return records, {"reason": "corrupt", "offset": off,
                             "detail": "payload CRC mismatch"}, off
        try:
            records.append(decode_payload(payload))
        except Exception as e:   # undecodable but CRC-clean: still cut
            return records, {"reason": "corrupt", "offset": off,
                             "detail": f"payload decode failed: {e!r}"}, off
        off = start + length
    return records, None, off


def cut_segment(path: str, valid_bytes: int) -> None:
    """Physically truncate a segment at its last valid record so the bad
    tail can never be re-read (recovery calls this after logging it)."""
    with open(path, "r+b") as f:
        f.truncate(max(int(valid_bytes), 0))
        f.flush()
        os.fsync(f.fileno())


class WriteAheadLog:
    """Append-only, segment-rotated, CRC-checked journal in one
    directory.

    Opening a directory always starts a *new* active segment at
    ``max(existing seq) + 1`` — existing segments are never appended to,
    so a recovering process can replay them while its own journal is
    already live, and a half-written tail from the previous life never
    shares a file with fresh records.
    """

    def __init__(self, wal_dir: str, *, fsync: str = "batch"):
        if fsync not in FSYNC_POLICIES:
            raise WALError(
                f"unknown fsync policy {fsync!r}: expected one of "
                f"{FSYNC_POLICIES}")
        self.dir = str(wal_dir)
        self.fsync = fsync
        os.makedirs(self.dir, exist_ok=True)
        self.records_appended = 0
        self.bytes_appended = 0
        self.syncs = 0
        self.tail_events: List[Dict] = []
        taken = [s for s, _ in list_segments(self.dir)]
        taken += [s for s, _ in list_snapshots(self.dir)]
        self._seq = (max(taken) + 1) if taken else 0
        self._file = None
        self._open_segment()

    # ------------------------------------------------------------- writing
    def _open_segment(self) -> None:
        self._file = open(segment_path(self.dir, self._seq), "xb")
        self._file.write(_SEG_HEADER.pack(SEGMENT_MAGIC, SEGMENT_VERSION))
        self._file.flush()
        if self.fsync != "off":
            os.fsync(self._file.fileno())

    @property
    def active_seq(self) -> int:
        return self._seq

    @property
    def active_path(self) -> str:
        return segment_path(self.dir, self._seq)

    def append(self, kind: str, meta: Optional[Dict] = None,
               arrays: Optional[Dict[str, np.ndarray]] = None) -> int:
        """Append one record; returns its 0-based index within this
        WAL's lifetime.  Under ``fsync='always'`` the record is on disk
        when this returns; under ``batch``/``off`` it is in the OS page
        cache (flushed, so a *process* crash loses nothing)."""
        if self._file is None:
            raise WALError("append on a closed WAL")
        rec = encode_record(kind, meta, arrays)
        self._file.write(rec)
        self._file.flush()
        if self.fsync == "always":
            os.fsync(self._file.fileno())
            self.syncs += 1
        idx = self.records_appended
        self.records_appended += 1
        self.bytes_appended += len(rec)
        return idx

    def sync(self) -> None:
        """Batch-policy barrier: fsync the active segment (no-op under
        ``off``; redundant under ``always``)."""
        if self._file is not None and self.fsync == "batch":
            os.fsync(self._file.fileno())
            self.syncs += 1

    def rotate(self) -> int:
        """Seal the active segment and open the next one; returns the new
        segment's sequence number (the checkpoint key)."""
        f, self._file = self._file, None
        if f is not None:
            f.flush()
            if self.fsync != "off":
                os.fsync(f.fileno())
            f.close()
        self._seq += 1
        self._open_segment()
        return self._seq

    def close(self) -> None:
        f, self._file = self._file, None
        if f is not None:
            f.flush()
            if self.fsync != "off":
                os.fsync(f.fileno())
            f.close()

    # ------------------------------------------------------------- reading
    def replay(self, from_seq: int) -> Iterator[WALRecord]:
        """Yield every record of every *sealed* segment with
        ``seq >= from_seq``, in order, cutting at the first torn or
        corrupted record (logged in ``tail_events``, physically
        truncated).  Records after a cut are never yielded — replay
        order must match append order, and a gap breaks that promise."""
        self.tail_events = []
        for seq, path in list_segments(self.dir):
            if seq < int(from_seq) or seq >= self._seq:
                continue        # pre-snapshot history / our own segment
            records, bad, valid = read_segment(path)
            if bad is not None:
                self.tail_events.append(
                    {"segment": seq, "records_kept": len(records), **bad})
                cut_segment(path, valid)
            yield from records
            if bad is not None:
                return

    # ----------------------------------------------------------------- GC
    def gc(self, keep_from_seq: int) -> List[str]:
        """Delete sealed segments and snapshots with ``seq <
        keep_from_seq`` plus stray ``*.tmp`` files (interrupted atomic
        snapshot writes); returns the removed paths."""
        removed = []
        for seq, path in list_segments(self.dir):
            if seq < int(keep_from_seq) and seq != self._seq:
                os.remove(path)
                removed.append(path)
        for seq, path in list_snapshots(self.dir):
            if seq < int(keep_from_seq):
                os.remove(path)
                removed.append(path)
        for name in os.listdir(self.dir):
            if name.endswith(".tmp"):
                path = os.path.join(self.dir, name)
                os.remove(path)
                removed.append(path)
        return removed

    def stats(self) -> Dict:
        return {
            "dir": self.dir,
            "fsync": self.fsync,
            "active_seq": self._seq,
            "records_appended": self.records_appended,
            "bytes_appended": self.bytes_appended,
            "syncs": self.syncs,
            "segments": len(list_segments(self.dir)),
            "snapshots": len(list_snapshots(self.dir)),
        }


# --------------------------------------------------------- atomic snapshots
def snapshot_checksum(meta: Dict, arrays: Dict[str, np.ndarray]) -> int:
    """Deterministic whole-snapshot checksum: CRC32 over the canonical
    meta JSON (checksum field excluded) and every array's name + raw
    little-endian bytes, in sorted key order."""
    clean = {k: v for k, v in meta.items() if k != "checksum"}
    c = zlib.crc32(json.dumps(clean, sort_keys=True).encode())
    for name in sorted(arrays):
        a = np.ascontiguousarray(arrays[name])
        a = a.astype(a.dtype.newbyteorder("<"), copy=False)
        c = zlib.crc32(name.encode(), c)
        c = zlib.crc32(a.tobytes(), c)
    return c


def write_snapshot_atomic(path_or_file, meta: Dict,
                          arrays: Dict[str, np.ndarray]) -> None:
    """Persist one snapshot as ``.npz`` with the whole-file checksum
    embedded in the meta record.  File-path targets are written to a
    sibling ``.tmp`` and ``os.replace``d — a crash mid-write leaves the
    previous snapshot untouched and at worst a stray tmp (GC'd)."""
    meta = dict(meta)
    meta["checksum"] = snapshot_checksum(meta, arrays)
    blob = np.frombuffer(json.dumps(meta, sort_keys=True).encode(),
                         dtype=np.uint8)
    if isinstance(path_or_file, (str, os.PathLike)):
        path = os.fspath(path_or_file)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, meta=blob, **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        # fsync the directory so the rename itself survives power loss
        try:
            dfd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
        except OSError:        # pragma: no cover - exotic filesystems
            pass
    else:
        np.savez(path_or_file, meta=blob, **arrays)


class SnapshotCorruption(WALError):
    """A snapshot file failed its checksum or could not be parsed —
    recovery falls back to the previous retained snapshot."""


def read_snapshot(path_or_file) -> Tuple[Dict, Dict[str, np.ndarray]]:
    """Inverse of :func:`write_snapshot_atomic`; verifies the embedded
    checksum (when present — pre-durability snapshots lack it) and
    raises :class:`SnapshotCorruption` on any mismatch or parse error."""
    try:
        with np.load(path_or_file, allow_pickle=False) as z:
            meta = json.loads(bytes(z["meta"]).decode())
            arrays = {k: z[k] for k in z.files if k != "meta"}
    except SnapshotCorruption:
        raise
    except Exception as e:
        raise SnapshotCorruption(f"unreadable snapshot: {e!r}") from e
    want = meta.get("checksum")
    if want is not None and snapshot_checksum(meta, arrays) != int(want):
        raise SnapshotCorruption("snapshot checksum mismatch")
    return meta, arrays
