"""Property-based tests (hypothesis) for the paper's invariants."""

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: vendored seeded fallback
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import TCQEngine, TemporalGraph, brute_force_query
from repro.core.oracle import peel_window


@st.composite
def temporal_graphs(draw, max_v=12, max_e=50, max_t=10):
    n_v = draw(st.integers(3, max_v))
    n_e = draw(st.integers(1, max_e))
    edges = draw(st.lists(
        st.tuples(st.integers(0, n_v - 1), st.integers(0, n_v - 1),
                  st.integers(1, max_t)),
        min_size=n_e, max_size=n_e))
    return TemporalGraph.from_edge_list(edges, num_vertices=n_v)


@settings(max_examples=60, deadline=None)
@given(temporal_graphs(), st.integers(1, 4), st.integers(1, 2))
def test_otcd_equals_oracle(g, k, h):
    if g.num_edges == 0:
        return
    Ts, Te = g.span
    oracle = brute_force_query(g, k, Ts, Te, h)
    res = TCQEngine(g).query(k, Ts, Te, h=h)
    assert set(c.tti for c in res.cores) == set(oracle.keys())
    for c in res.cores:
        assert set(c.vertices.tolist()) == set(oracle[c.tti]["vertices"])
        assert c.n_edges == oracle[c.tti]["n_edges"]


@settings(max_examples=30, deadline=None)
@given(temporal_graphs(), st.integers(1, 4))
def test_wave_equals_serial(g, k):
    if g.num_edges == 0:
        return
    Ts, Te = g.span
    eng = TCQEngine(g)
    a = eng.query(k, Ts, Te)
    b = eng.query(k, Ts, Te, mode="wave", wave=5)
    assert a.by_tti().keys() == b.by_tti().keys()


@settings(max_examples=30, deadline=None)
@given(temporal_graphs(), st.integers(1, 3))
def test_tti_inclusion_property(g, k):
    """Paper Property 3: [ts,te] ⊆ [ts',te'] => TTI ⊆ TTI'."""
    if g.num_edges == 0:
        return
    Ts, Te = g.span
    mid = (Ts + Te) // 2
    em_small = peel_window(g, Ts, mid, k)
    em_big = peel_window(g, Ts, Te, k)
    if em_small.any() and em_big.any():
        lo_s, hi_s = g.t[em_small].min(), g.t[em_small].max()
        lo_b, hi_b = g.t[em_big].min(), g.t[em_big].max()
        assert lo_b <= lo_s and hi_s <= hi_b


@settings(max_examples=30, deadline=None)
@given(temporal_graphs(), st.integers(1, 3))
def test_tti_fixpoint_property(g, k):
    """Theorem 2 + Property 1: re-peeling a core over its own TTI returns the
    identical core (TTI is tight and unique)."""
    if g.num_edges == 0:
        return
    Ts, Te = g.span
    em = peel_window(g, Ts, Te, k)
    if not em.any():
        return
    lo, hi = int(g.t[em].min()), int(g.t[em].max())
    em2 = peel_window(g, lo, hi, k)
    assert np.array_equal(em, em2)


@settings(max_examples=25, deadline=None)
@given(temporal_graphs(), st.integers(1, 3))
def test_monotone_in_k(g, k):
    """(k+1)-cores are subgraphs of k-cores (classic nesting), and the number
    of distinct cores is non-increasing in k (paper Fig. 10 rationale)."""
    if g.num_edges == 0:
        return
    Ts, Te = g.span
    em_k = peel_window(g, Ts, Te, k)
    em_k1 = peel_window(g, Ts, Te, k + 1)
    assert not np.any(em_k1 & ~em_k)
    eng = TCQEngine(g)
    assert len(eng.query(k + 1, Ts, Te)) <= len(eng.query(k, Ts, Te))


@settings(max_examples=25, deadline=None)
@given(temporal_graphs(), st.integers(1, 3))
def test_monotone_in_h(g, k):
    """Link-strength: raising h only shrinks cores (paper §6.2)."""
    if g.num_edges == 0:
        return
    Ts, Te = g.span
    em1 = peel_window(g, Ts, Te, k, h=1)
    em2 = peel_window(g, Ts, Te, k, h=2)
    assert not np.any(em2 & ~em1)


@settings(max_examples=25, deadline=None)
@given(temporal_graphs(), st.integers(1, 3))
def test_warm_start_invariance(g, k):
    """Theorem 1: peeling warm-started from any superset core equals the
    cold-start result — checked through the device engine."""
    import jax.numpy as jnp

    from repro.core.tcd import tcd

    if g.num_edges == 0:
        return
    Ts, Te = g.span
    tel = g.device_tel()
    ones = jnp.ones((g.num_vertices,), dtype=bool)
    big = tcd(tel, ones, Ts, Te, k, 1, num_vertices=g.num_vertices)
    mid = (Ts + Te) // 2
    cold = tcd(tel, ones, Ts, mid, k, 1, num_vertices=g.num_vertices)
    warm = tcd(tel, big.alive, Ts, mid, k, 1, num_vertices=g.num_vertices)
    assert np.array_equal(np.asarray(cold.alive), np.asarray(warm.alive))


def test_pruning_accounting_is_exact():
    """evaluated + pruned + trivially-empty cells cover the whole schedule."""
    from repro.graphs import planted_cores

    g = planted_cores(seed=3)
    s = TCQEngine(g).query(3, 1, 40).stats
    covered = (s.cells_evaluated + s.pruned_total + s.pruned_empty
               + s.cells_trivial)
    assert covered == s.cells_total
    assert 0 <= s.pruned_pct() <= 100.0


def test_span_constraint_filter():
    from repro.graphs import planted_cores

    g = planted_cores(seed=3)
    res = TCQEngine(g).query(3, 1, 40, max_span=3)
    assert all(c.span <= 3 for c in res.cores)
    full = TCQEngine(g).query(3, 1, 40)
    expect = [c for c in full.cores if c.span <= 3]
    assert len(res) == len(expect)
    top = full.top_n_shortest_span(3)
    assert len(top) == 3
    assert top[0].span <= top[-1].span
