"""The paper's baseline: PHC-Index + incremental PHC query (Algorithm 1).

PHC-Index precomputes, per anchored start time ts and the queried k, each
vertex's *core time* — the earliest end time te at which the vertex's
coreness over [ts, te] reaches k.  The online iPHC query then sweeps te
ascending per row, popping qualified vertices from a core-time heap and
churning edges through a timestamp heap exactly as the paper's Algorithm 1
does (including the push-back of edges whose endpoints are not yet in V).

The offline build is the paper's admitted weakness (quadratic in the number
of timestamps); we build it with the shared device peel (warm-started from
the row's largest core, which is a valid superset for every column — Theorem
1), which is *charitable* to the baseline: the benchmark comparisons in
benchmarks/ measure its online phase only, plus the build cost reported
separately, mirroring the paper's setup.
"""

from __future__ import annotations

import heapq
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.graph import TemporalGraph
from repro.core.results import CoreResult, QueryStats, TCQResult

_INF = np.iinfo(np.int64).max


class PHCIndex:
    """core_time[i, v] = smallest column j (unique-ts index) with
    coreness_{[uts[i], uts[j]]}(v) >= k; _INF if never."""

    def __init__(self, graph: TemporalGraph, k: int, Ts: int, Te: int):
        from repro.core.otcd import TCQEngine  # local: avoid cycle

        self.graph = graph
        self.k = k
        uts = graph.unique_ts
        self.uts = uts[(uts >= Ts) & (uts <= Te)].astype(np.int64)
        n = self.uts.size
        self.core_time = np.full((n, graph.num_vertices), _INF, dtype=np.int64)
        eng = TCQEngine(graph)
        t0 = time.perf_counter()
        import jax.numpy as jnp
        for i in range(n):
            # row-largest core = valid warm start for every column of the row
            top = eng._tcd(eng._ones, int(self.uts[i]), int(self.uts[-1]),
                           k, 1)
            top_alive = top.alive
            if int(top.n_verts) == 0:
                continue
            remaining = np.asarray(top_alive).copy()
            for j in range(i, n):
                if not remaining.any():
                    break
                res = eng._tcd(top_alive, int(self.uts[i]), int(self.uts[j]),
                               k, 1)
                got = np.asarray(res.alive) & remaining
                if got.any():
                    self.core_time[i, np.flatnonzero(got)] = j
                    remaining &= ~got
        self.build_time_s = time.perf_counter() - t0

    def nbytes(self) -> int:
        return self.core_time.nbytes


def iphc_query(graph: TemporalGraph, index: PHCIndex, k: int,
               Ts: int, Te: int) -> TCQResult:
    """Paper Algorithm 1 — incremental historical-core query per row."""
    t0 = time.perf_counter()
    uts = index.uts
    n = uts.size
    stats = QueryStats(n_timestamps=n, cells_total=n * (n + 1) // 2)
    results: Dict[Tuple[int, int], CoreResult] = {}
    t_arr, src, dst = graph.t.astype(np.int64), graph.src, graph.dst
    for i in range(n):
        ct = index.core_time[i]
        hv: List[Tuple[int, int]] = [
            (int(ct[v]), int(v)) for v in np.flatnonzero(ct < _INF)]
        heapq.heapify(hv)
        if not hv:
            continue
        emask = (t_arr >= uts[i]) & (t_arr <= uts[-1])
        he: List[Tuple[int, int]] = [
            (int(t_arr[e]), int(e)) for e in np.flatnonzero(emask)]
        heapq.heapify(he)
        vset: set = set()
        eset: set = set()
        deferred: List[Tuple[int, int]] = []
        for j in range(i, n):
            stats.cells_evaluated += 1
            while hv and hv[0][0] <= j:
                vset.add(heapq.heappop(hv)[1])
            # re-push deferred edges now that V may have grown (the paper's
            # line 8 push-back churn)
            for item in deferred:
                heapq.heappush(he, item)
            deferred = []
            while he and he[0][0] <= uts[j]:
                tt, e = heapq.heappop(he)
                if int(src[e]) in vset and int(dst[e]) in vset:
                    eset.add(e)
                else:
                    deferred.append((tt, e))
            if not eset:
                continue
            ets = [int(t_arr[e]) for e in eset]
            key = (min(ets), max(ets))
            if key not in results:
                results[key] = CoreResult(
                    k=k, tti=key,
                    vertices=np.array(sorted(
                        set(int(src[e]) for e in eset)
                        | set(int(dst[e]) for e in eset)), dtype=np.int64),
                    n_edges=len(eset))
            else:
                stats.duplicates += 1
    stats.wall_time_s = time.perf_counter() - t0
    return TCQResult(list(results.values()), stats)
