"""Pipelined wave engine equivalence + packed-transfer round trips.

Seeded (non-hypothesis) property matrix: the device-resident pipeline
(mode="wave") must return *exactly* the serial engine's result set —
same TTIs, same vertex sets, same edge counts — across random graphs ×
k × h × span × wave width.  (The seed stepwise engine that used to sit
between them was retired after PR 2; requesting it must fail loudly.)
Plus unit tests for the uint32 bitmask pack/unpack pair and the
distributed engine's packed result transfer.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import TCQEngine, TemporalGraph
from repro.core.engine import (pack_alive_u32, packed_width,
                               unpack_alive_u32)


def random_graph(seed: int, n_v: int = 20, n_e: int = 120, max_t: int = 16):
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n_v, n_e)
    v = rng.integers(0, n_v, n_e)
    t = rng.integers(1, max_t + 1, n_e)
    return TemporalGraph.from_edges(u, v, t, num_vertices=n_v)


def assert_same_results(a, b):
    assert a.by_tti().keys() == b.by_tti().keys()
    for key, ca in a.by_tti().items():
        cb = b.by_tti()[key]
        assert np.array_equal(ca.vertices, cb.vertices), key
        assert ca.n_edges == cb.n_edges, key


@pytest.mark.parametrize("seed,k,h,span,wave", [
    (0, 2, 1, 1.0, 4),
    (1, 3, 1, 1.0, 8),
    (2, 2, 2, 1.0, 5),
    (3, 4, 1, 0.5, 3),
    (4, 2, 1, 0.4, 16),
    (5, 3, 2, 0.6, 2),
    (6, 1, 1, 1.0, 7),
])
def test_wave_modes_equal_serial(seed, k, h, span, wave):
    g = random_graph(seed)
    Ts, Te = g.span
    Te = Ts + max(1, int((Te - Ts) * span))
    eng = TCQEngine(g)
    serial = eng.query(k, Ts, Te, h=h)
    pipelined = eng.query(k, Ts, Te, h=h, mode="wave", wave=wave)
    assert_same_results(serial, pipelined)


def test_retired_stepwise_mode_raises():
    g = random_graph(0)
    Ts, Te = g.span
    with pytest.raises(ValueError, match="wave_stepwise"):
        TCQEngine(g).query(2, Ts, Te, mode="wave_stepwise")


def test_wave_on_dense_planted_graph():
    from repro.graphs import planted_cores

    g = planted_cores(seed=7)
    eng = TCQEngine(g)
    a = eng.query(3, 1, 40)
    b = eng.query(3, 1, 40, mode="wave", wave=6)
    assert_same_results(a, b)
    # pipeline accounting: every evaluated cell ran on some device step,
    # and results moved as packed words + scalar vectors only
    s = b.stats
    assert s.device_steps > 0 and s.host_syncs == s.device_steps
    w32 = packed_width(g.num_vertices)
    per_step = 6 * w32 * 4 + 6 * 4 * 3 + 4   # packed + lo/hi/ne + iters
    assert s.bytes_synced <= s.device_steps * per_step


def test_wave_with_forced_pallas_kernel():
    """Same results when the degree path runs the Pallas kernel
    (interpret mode on CPU)."""
    g = random_graph(11, n_v=16, n_e=80, max_t=8)
    Ts, Te = g.span
    ref = TCQEngine(g, use_kernel=False).query(2, Ts, Te, mode="wave")
    ker = TCQEngine(g, use_kernel=True).query(2, Ts, Te, mode="wave")
    assert_same_results(ref, ker)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_wave_windowed_tel_sub_span(use_kernel):
    """Sub-span queries peel against the truncated (sentinel-padded) TEL;
    results must match serial on the full TEL — on both degree paths
    (the Pallas path rebuilds its pair closure per window)."""
    g = random_graph(12, n_v=18, n_e=150, max_t=20)
    Ts, Te = g.span
    mid_lo, mid_hi = Ts + (Te - Ts) // 4, Ts + (3 * (Te - Ts)) // 4
    eng = TCQEngine(g, use_kernel=use_kernel)
    serial = eng.query(2, mid_lo, mid_hi)
    pipe = eng.query(2, mid_lo, mid_hi, mode="wave", wave=4)
    assert_same_results(serial, pipe)
    # the window cache was populated (the window is a strict edge subset)
    assert eng._win_cache


@pytest.mark.parametrize("num_vertices", [1, 31, 32, 33, 64, 100, 257])
def test_pack_unpack_roundtrip(num_vertices):
    rng = np.random.default_rng(num_vertices)
    masks = rng.random((5, num_vertices)) < 0.3
    packed = np.asarray(pack_alive_u32(jnp.asarray(masks),
                                       num_vertices=num_vertices))
    assert packed.shape == (5, packed_width(num_vertices))
    assert packed.dtype == np.uint32
    assert np.array_equal(unpack_alive_u32(packed, num_vertices), masks)


def test_pack_unpack_single_row():
    v = 70
    mask = np.zeros(v, bool)
    mask[[0, 31, 32, 63, 64, 69]] = True
    packed = np.asarray(pack_alive_u32(jnp.asarray(mask), num_vertices=v))
    assert np.array_equal(unpack_alive_u32(packed, v), mask)


def test_distributed_packed_transfer_matches_bool():
    import jax

    from repro.core.distributed import DistributedTCQ
    from repro.graphs import planted_cores

    g = planted_cores(seed=3)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = DistributedTCQ(g, mesh)
    ts, te, k = [1, 5, 10], [40, 30, 20], 3
    alive, lo, hi, ne, _ = eng.query_wave(ts, te, k)
    packed, lo2, hi2, ne2, _ = eng.query_wave(ts, te, k, packed=True)
    v = eng.plan.num_vertices
    assert np.array_equal(unpack_alive_u32(np.asarray(packed), v),
                          np.asarray(alive))
    assert np.array_equal(np.asarray(lo), np.asarray(lo2))
    assert np.array_equal(np.asarray(ne), np.asarray(ne2))


def test_add_edges_empty_batch_is_noop():
    """Regression: empty dynamic batch used to crash on np.max([])."""
    from repro.graphs import paper_style_example

    g = paper_style_example()
    g2 = g.add_edges([], [], [])
    assert g2 is g
    # malformed (mismatched-length) batches must still fail loudly
    with pytest.raises(ValueError):
        g.add_edges([], [5], [7])


def test_all_negative_timestamps_match_oracle():
    """Regression: the tti_hi empty-fill was -1, which clamped TTIs for
    cores whose edges all have t < -1 (wrong keys in EVERY mode, or a
    KeyError on collection).  Now int32 min, like tti_lo's I32_MAX."""
    from repro.core import brute_force_query

    rng = np.random.default_rng(5)
    n_v, n_e = 12, 80
    u = rng.integers(0, n_v, n_e)
    v = rng.integers(0, n_v, n_e)
    t = rng.integers(-50, -2, n_e)
    g = TemporalGraph.from_edges(u, v, t, num_vertices=n_v)
    Ts, Te = g.span
    oracle = brute_force_query(g, 2, Ts, Te)
    eng = TCQEngine(g)
    for mode in ("serial", "wave"):
        kw = {} if mode == "serial" else {"mode": mode}
        res = eng.query(2, Ts, Te, **kw)
        assert set(c.tti for c in res.cores) == set(oracle.keys()), mode
        for c in res.cores:
            assert set(c.vertices.tolist()) == set(
                oracle[c.tti]["vertices"]), (mode, c.tti)


def test_wave_negative_timestamps():
    """Regression: the windowed TEL's sentinel padding must not collide
    with real negative timestamps (pad was t=-1; now int32 min)."""
    rng = np.random.default_rng(3)
    n_v, n_e = 14, 90
    u = rng.integers(0, n_v, n_e)
    v = rng.integers(0, n_v, n_e)
    t = rng.integers(-8, 8, n_e)
    g = TemporalGraph.from_edges(u, v, t, num_vertices=n_v)
    eng = TCQEngine(g)
    for lo, hi in [(-6, 6), (-8, -1), (-3, 7)]:
        serial = eng.query(2, lo, hi)
        wave = eng.query(2, lo, hi, mode="wave", wave=4)
        assert_same_results(serial, wave)
