"""Seeded fault injection for the TCQ serving stack (chaos harness).

Faults are injected at the *wave-step seam*: every engine backend — fused
Pallas kernel, XLA composite, numpy oracle — is a step closure with the
same signature, and the degradation ladder
(:class:`repro.core.wave.DegradationLadder`) already wraps each rung via
``ResilienceConfig.rung_wrapper``.  :func:`rung_faults` builds such a
wrapper from per-rung :class:`FaultPlan`\\ s, so a chaos scenario is just
an engine constructed with ``resilience=ResilienceConfig(rung_wrapper=
rung_faults({"pallas": FaultPlan(fail_at=(0,))}))`` — no test-only hooks
inside the engine itself.

Everything is keyed by a deterministic per-rung *call counter* (never
wall clock or RNG state shared with the engine), so a scenario replays
bit-identically: the same calls fail, stall, or corrupt on every run.

Fault classes:

* ``fail_at`` — the step raises :class:`KernelFault` (models a compile
  failure, an XLA runtime abort, a device OOM).  The ladder demotes to
  the next rung and replays the same inputs.
* ``slow_at`` — the step sleeps ``delay_s`` before running (models a
  straggler lane / a thermally throttled device).  Results are
  unaffected; only latency moves.
* ``corrupt_at`` — the step's result comes back with the alive-mask of
  every lane flipped at ``corrupt_vertex`` (models silent data
  corruption).  The ladder's sampled oracle tripwire is the only thing
  standing between this and a wrong answer.

:func:`malformed_batches` supplies ingest batches that must be rejected
by ``TemporalGraph``'s validation (:class:`~repro.core.graph.
GraphIngestError`) without perturbing the graph.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Mapping, Optional, Tuple

import numpy as np


class KernelFault(RuntimeError):
    """Injected kernel failure (stands in for compile/runtime/OOM errors)."""


# ---------------------------------------------------------------- fault plan
@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic fault schedule for one ladder rung, keyed by the
    rung's 0-based call counter."""

    fail_at: Tuple[int, ...] = ()       # calls that raise KernelFault
    slow_at: Tuple[int, ...] = ()       # calls delayed by ``delay_s``
    corrupt_at: Tuple[int, ...] = ()    # calls whose alive-mask is flipped
    delay_s: float = 0.05
    corrupt_vertex: int = 0


class FaultyStep:
    """Wrap a wave step closure with a :class:`FaultPlan`.

    Transparent otherwise: attribute reads (``backend``, ``interpret``,
    ``events``) fall through to the wrapped step, so the ladder — and the
    engine's logging — see the rung they expect.
    """

    def __init__(self, fn: Callable, plan: FaultPlan):
        self._fn = fn
        self._plan = plan
        self.calls = 0

    def __getattr__(self, name):
        return getattr(self._fn, name)

    def __call__(self, *args, **kwargs):
        i = self.calls
        self.calls += 1
        plan = self._plan
        if i in plan.fail_at:
            raise KernelFault(f"injected kernel failure (call {i})")
        if i in plan.slow_at:
            time.sleep(plan.delay_s)
        res = self._fn(*args, **kwargs)
        if i in plan.corrupt_at:
            vtx = plan.corrupt_vertex
            # flip every lane's alive bit at one vertex: guaranteed to
            # differ from truth whichever lane the tripwire samples
            res = res._replace(
                alive=res.alive.at[:, vtx].set(~res.alive[:, vtx]))
        return res


def rung_faults(plans: Mapping[str, FaultPlan]
                ) -> Callable[[str, Callable], Callable]:
    """``ResilienceConfig.rung_wrapper`` injecting per-rung fault plans.

    ``plans`` maps rung names (``"pallas"``, ``"xla"``, ``"oracle"``) to
    their schedules; unplanned rungs pass through unwrapped.  Injecting
    into ``"oracle"`` is allowed but note the ladder re-raises once its
    last rung fails.
    """
    def wrapper(name: str, fn: Callable) -> Callable:
        plan = plans.get(name)
        return fn if plan is None else FaultyStep(fn, plan)
    return wrapper


# ---------------------------------------------------------- malformed ingest
def malformed_batches(seed: int = 0
                      ) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Ingest batches that ``TemporalGraph.add_edges`` must reject with
    :class:`~repro.core.graph.GraphIngestError` — one per validation
    class, seeded order."""
    i32 = np.iinfo(np.int32)
    batches = [
        # negative vertex id
        (np.array([-1, 2]), np.array([3, 4]), np.array([5, 6])),
        # fractional float id
        (np.array([1.5, 2.0]), np.array([3.0, 4.0]), np.array([5.0, 6.0])),
        # NaN timestamp
        (np.array([1, 2]), np.array([3, 4]), np.array([np.nan, 6.0])),
        # shape mismatch
        (np.array([1, 2, 3]), np.array([3, 4]), np.array([5, 6])),
        # id overflows the int32 pair-key packing
        (np.array([1 << 40, 2]), np.array([3, 4]), np.array([5, 6])),
        # timestamp collides with the int32-min padding sentinel
        (np.array([1, 2]), np.array([3, 4]), np.array([i32.min, 6])),
        # non-numeric dtype
        (np.array(["a", "b"]), np.array([3, 4]), np.array([5, 6])),
    ]
    rng = np.random.default_rng(seed)
    rng.shuffle(batches)
    return batches
