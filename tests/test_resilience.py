"""Fault-tolerance gates: degradation ladder, ingest validation, ticket
deadlines/backpressure, and crash recovery.

The load-bearing properties:

1. **Ladder equivalence** — the numpy oracle step is bit-identical to
   the XLA composite (alive, packed words, TTI, n_edges, iteration
   count), so demotion never changes an answer, only who computes it.
2. **Demotion correctness** — injected kernel failures, a starved VMEM
   budget, and silent result corruption each demote to the next rung
   and *replay the same inputs* bit-identically; a healthy ladder is
   invisible (no events, same results).
3. **Ingest validation** — malformed edge batches raise
   ``GraphIngestError`` before any state mutates.
4. **Deadlines and backpressure** — EDF ordering, terminal ticket
   statuses, bounded-queue shedding.
5. **Crash recovery** — snapshot → ``.npz`` → restore → drain equals
   the uninterrupted run, ticket for ticket.
"""

import io

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GraphIngestError, ResilienceConfig, TCQEngine,
                        TCQService, TemporalGraph)
from repro.core.faultinject import (FaultPlan, KernelFault,
                                    malformed_batches, rung_faults)
from repro.core.wave import make_oracle_step_fn, make_wave_step_fn


def random_graph(seed, n_v=20, n_e=140, max_t=16):
    rng = np.random.default_rng(seed)
    return TemporalGraph.from_edges(rng.integers(0, n_v, n_e),
                                    rng.integers(0, n_v, n_e),
                                    rng.integers(1, max_t + 1, n_e), n_v)


def random_lanes(seed, g, w=4):
    rng = np.random.default_rng(seed + 1000)
    lo, hi = g.span
    ts = rng.integers(lo, hi + 1, w).astype(np.int32)
    te = np.minimum(ts + rng.integers(1, hi - lo + 1, w), hi).astype(np.int32)
    k = rng.integers(1, 4, w).astype(np.int32)
    h = rng.integers(1, 3, w).astype(np.int32)
    alive = jnp.ones((w, g.num_vertices), jnp.bool_)
    return alive, ts, te, k, h


def assert_steps_equal(got, want, *, iters=True):
    fields = ["alive", "packed", "tti_lo", "tti_hi", "n_edges"]
    if iters:
        fields.append("iters")
    for f in fields:
        a, b = np.asarray(getattr(got, f)), np.asarray(getattr(want, f))
        assert np.array_equal(a, b), f


def assert_same(got, want, ctx=""):
    assert got.by_tti().keys() == want.by_tti().keys(), ctx
    for key, cw in want.by_tti().items():
        cg = got.by_tti()[key]
        assert np.array_equal(cg.vertices, cw.vertices), (ctx, key)
        assert cg.n_edges == cw.n_edges, (ctx, key)


# --------------------------------------------------- oracle rung equivalence
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_oracle_step_matches_composite(seed):
    g = random_graph(seed)
    tel = g.device_tel()
    comp = make_wave_step_fn(tel, g.num_vertices, use_kernel=False)
    oracle = make_oracle_step_fn(tel, g.num_vertices)
    assert oracle.backend == "oracle"
    alive, ts, te, k, h = random_lanes(seed, g)
    # bit-identical including the shared fixpoint iteration count
    assert_steps_equal(oracle(alive, ts, te, k, h),
                       comp(alive, ts, te, k, h))


# ------------------------------------------------------- ladder transitions
def _ladder(g, seed=0, **cfg_kw):
    tel = g.device_tel()
    cfg = ResilienceConfig(seed=seed, **cfg_kw)
    step = make_wave_step_fn(tel, g.num_vertices,
                             use_kernel=False, resilience=cfg)
    ref = make_wave_step_fn(tel, g.num_vertices, use_kernel=False)
    return step, ref


def test_ladder_invisible_when_healthy():
    g = random_graph(7)
    step, ref = _ladder(g)
    alive, ts, te, k, h = random_lanes(7, g)
    assert_steps_equal(step(alive, ts, te, k, h), ref(alive, ts, te, k, h))
    assert step.backend == "xla" and step.events == []


def test_ladder_demotes_on_error_and_replays():
    g = random_graph(8)
    step, ref = _ladder(g, rung_wrapper=rung_faults(
        {"xla": FaultPlan(fail_at=(0,))}))
    alive, ts, te, k, h = random_lanes(8, g)
    # call 0 raises inside the XLA rung; the ladder must return the
    # oracle's answer for the *same* inputs
    assert_steps_equal(step(alive, ts, te, k, h), ref(alive, ts, te, k, h))
    assert step.backend == "oracle"
    assert [e["reason"] for e in step.events] == ["error"]


def test_ladder_vmem_budget_starves_pallas_rung():
    g = random_graph(9)
    tel = g.device_tel()
    cfg = ResilienceConfig(interpret=False, vmem_budget_bytes=1)
    step = make_wave_step_fn(tel, g.num_vertices, use_kernel=True,
                             resilience=cfg)
    ref = make_wave_step_fn(tel, g.num_vertices, use_kernel=False)
    # the fused rung never built: ladder opens on XLA, with the event
    assert step.backend == "xla"
    assert [e["reason"] for e in step.events] == ["vmem_budget"]
    alive, ts, te, k, h = random_lanes(9, g)
    assert_steps_equal(step(alive, ts, te, k, h), ref(alive, ts, te, k, h))


def test_ladder_tripwire_catches_silent_corruption():
    g = random_graph(10)
    step, ref = _ladder(g, tripwire_every=1, rung_wrapper=rung_faults(
        {"xla": FaultPlan(corrupt_at=(0,), corrupt_vertex=3)}))
    alive, ts, te, k, h = random_lanes(10, g)
    # the corrupted result must never escape: sampled oracle cross-check
    # trips, the rung is quarantined, the call replays on the oracle
    assert_steps_equal(step(alive, ts, te, k, h), ref(alive, ts, te, k, h))
    assert step.backend == "oracle"
    assert [e["reason"] for e in step.events] == ["divergence"]


def test_ladder_last_rung_failure_raises():
    g = random_graph(11)
    step, _ = _ladder(g, rung_wrapper=rung_faults(
        {"xla": FaultPlan(fail_at=(0,)), "oracle": FaultPlan(fail_at=(0,))}))
    alive, ts, te, k, h = random_lanes(11, g)
    with pytest.raises(KernelFault):
        step(alive, ts, te, k, h)


# -------------------------------------------------------- ingest validation
def test_malformed_batches_rejected_before_mutation():
    g = random_graph(0)
    want = {f: np.asarray(getattr(g, f)).copy()
            for f in ("src", "dst", "t", "pair_id")}
    for u, v, t in malformed_batches(0):
        with pytest.raises(GraphIngestError):
            g.add_edges(u, v, t)
    for f, arr in want.items():
        assert np.array_equal(np.asarray(getattr(g, f)), arr), f


def test_from_edges_validates_too():
    with pytest.raises(GraphIngestError):
        TemporalGraph.from_edges([0, -2], [1, 3], [4, 5])
    with pytest.raises(GraphIngestError):
        TemporalGraph.from_edges([0, 1], [1, 3], [4.5, 5.0])
    # vertex ids beyond a declared num_vertices are rejected
    with pytest.raises(GraphIngestError):
        TemporalGraph.from_edges([0, 9], [1, 3], [4, 5], num_vertices=5)


def test_strict_mode_rejects_self_loops_and_negative_ts():
    g = random_graph(1)
    # lenient (default): self-loops silently dropped, negative ts kept
    g2 = g.add_edges([3], [3], [5])
    assert g2 is g
    g3 = g.add_edges([1], [2], [-4])
    assert g3.num_edges == g.num_edges + 1
    # strict: both are ingest errors
    with pytest.raises(GraphIngestError):
        g.add_edges([3], [3], [5], strict=True)
    with pytest.raises(GraphIngestError):
        g.add_edges([1], [2], [-4], strict=True)


def test_graph_state_dict_roundtrip():
    g = random_graph(2).add_edges([0, 1], [2, 3], [30, 31])
    g2 = TemporalGraph.from_state(g.state_dict())
    for f in ("src", "dst", "t", "pair_id", "pair_u", "pair_v",
              "unique_ts"):
        a, b = np.asarray(getattr(g, f)), np.asarray(getattr(g2, f))
        assert a.dtype == b.dtype and np.array_equal(a, b), f
    assert g2.num_vertices == g.num_vertices and g2.epoch == g.epoch


# -------------------------------------------------- deadlines / EDF / sheds
def _requests(g, n=4, seed=0):
    rng = np.random.default_rng(seed)
    uts = np.asarray(g.unique_ts)
    reqs = []
    for _ in range(n):
        i, j = sorted(rng.integers(0, uts.size, 2))
        reqs.append({"k": int(rng.integers(1, 4)),
                     "ts": int(uts[i]), "te": int(uts[min(j + 1, uts.size - 1)])})
    return reqs


def test_edf_serves_tight_deadline_first():
    g = random_graph(3)
    lo, hi = g.span
    mid = (lo + hi) // 2
    svc = TCQService(g)
    slack = svc.submit({"k": 2, "ts": lo, "te": mid})
    tight = svc.submit({"k": 2, "ts": mid + 1, "te": hi,
                        "deadline_s": 60.0})
    svc.pump()
    assert tight.done and tight.status == "done"
    assert not slack.done                   # disjoint window: next pool
    svc.run_until_idle()
    assert slack.status == "done"


def test_cancel_and_timeout_are_terminal_with_partial_results():
    g = random_graph(4)
    lo, hi = g.span
    svc = TCQService(g)
    a = svc.submit({"k": 2, "ts": lo, "te": hi})
    b = svc.submit({"k": 2, "ts": lo, "te": hi, "deadline_s": -1.0})
    assert svc.cancel(a) and a.status == "cancelled" and a.done
    assert a.result is not None and not svc.cancel(a)   # idempotent
    svc.run_until_idle()
    assert b.status == "timeout" and b.done and b.result is not None
    assert svc.pending == 0


def test_backpressure_bounded_queue_and_qps_ceiling():
    from repro.launch.serve import Backpressure

    g = random_graph(5)
    lo, hi = g.span
    req = {"k": 2, "ts": lo, "te": hi}
    svc = TCQService(g)
    bp = Backpressure(svc, queue_cap=2, deadline_s=30.0)
    t1, t2 = bp.offer(req), bp.offer(req)
    assert t1 is not None and t2 is not None
    assert t1.deadline is not None          # stamped by the gate
    assert bp.offer(req) is None            # queue full -> shed
    assert bp.shed == 1 and bp.offered == 3
    # a queued ticket past its deadline yields its slot to the arrival
    t1.deadline = 0.0
    t4 = bp.offer(req)
    assert t4 is not None and t1.status == "timeout"

    svc2 = TCQService(g)
    bp2 = Backpressure(svc2, queue_cap=1, qps_ceiling=1e-6)
    assert bp2.offer(req) is not None       # initial burst allowance
    # bucket drained, refill is ~0 at this qps: everything else sheds
    assert bp2.offer(req) is None and bp2.shed_rate == pytest.approx(0.5)


# ----------------------------------------------------------- crash recovery
@pytest.mark.parametrize("seed", [0, 1])
def test_snapshot_restore_equals_uninterrupted(seed):
    rng = np.random.default_rng(seed + 50)
    g = random_graph(seed)
    reqs = _requests(g, n=4, seed=seed)
    extra_u = rng.integers(0, g.num_vertices, 12)
    extra_v = rng.integers(0, g.num_vertices, 12)
    extra_t = rng.integers(20, 30, 12)

    # uninterrupted reference: submit, ingest, submit, drain
    ref = TCQService(g)
    ref_tks = [ref.submit(r) for r in reqs[:2]]
    ref.push_edges(extra_u, extra_v, extra_t)
    ref_tks += [ref.submit(r) for r in reqs[2:]]
    ref.run_until_idle()

    # crashed run: same traffic, pump once, snapshot through a real
    # .npz byte stream, restore, drain the remainder
    svc = TCQService(g)
    tks = [svc.submit(r) for r in reqs[:2]]
    svc.push_edges(extra_u, extra_v, extra_t)
    tks += [svc.submit(r) for r in reqs[2:]]
    early = svc.pump()
    buf = io.BytesIO()
    svc.save_snapshot(buf)
    buf.seek(0)
    svc2 = TCQService.load_snapshot(buf)
    assert svc2.epoch == svc.epoch
    late = svc2.run_until_idle()
    by_id = {tk.id: tk for tk in early + late}
    assert sorted(by_id) == sorted(tk.id for tk in ref_tks)
    for want in ref_tks:
        got = by_id[want.id]
        assert got.epoch == want.epoch      # epoch pins survive restore
        assert_same(got.result, want.result, ctx=f"ticket {want.id}")


def test_restore_preserves_deadlines_and_ids():
    g = random_graph(6)
    lo, hi = g.span
    svc = TCQService(g)
    svc.submit({"k": 2, "ts": lo, "te": hi, "deadline_s": 120.0,
                "priority": -3})
    snap = svc.snapshot()
    assert snap["tickets"][0]["deadline_rem_s"] == pytest.approx(120.0,
                                                                 abs=5.0)
    svc2 = TCQService.restore(snap)
    (tk,) = svc2.pending_tickets
    assert tk.id == 0 and tk.priority == -3 and tk.deadline is not None
    nxt = svc2.submit({"k": 2, "ts": lo, "te": hi})
    assert nxt.id == 1                      # id sequence continues


# ------------------------------------------- resilient service end-to-end
def test_service_with_injected_faults_matches_fault_free():
    g = random_graph(12, n_v=24, n_e=200)
    reqs = _requests(g, n=3, seed=12)
    plain = TCQService(g)
    want = [plain.submit(r) for r in reqs]
    plain.run_until_idle()

    cfg = ResilienceConfig(seed=12, tripwire_every=1,
                           rung_wrapper=rung_faults(
                               {"xla": FaultPlan(fail_at=(1,),
                                                 corrupt_at=(0,))}))
    svc = TCQService(g, resilience=cfg)
    got = [svc.submit(r) for r in reqs]
    svc.run_until_idle()
    assert svc.engine.resilience_events(), "faults never fired"
    for a, b in zip(got, want):
        assert_same(a.result, b.result, ctx=f"ticket {a.id}")
