"""Dispatching wrapper: Pallas banded segment-sum with XLA fallback.

On TPU the Pallas kernel runs compiled; on CPU it runs interpret=True
(used by tests); graphs whose band width exceeds ``k_cap`` (extreme hub
vertices) fall back to ``jax.ops.segment_sum``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.segdeg.kernel import banded_segsum_pallas, required_k_max
from repro.kernels.segdeg.ref import banded_segsum_ref


def on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def make_banded_segsum(seg_ids_host, num_segments: int, *, k_cap: int = 16,
                       s_tile: int = 128, n_tile: int = 512,
                       use_kernel: bool = True, interpret=None):
    """Build a segsum closure for one static graph (segment ids fixed).

    Returns fn(values [N, Q], seg_ids [N]) -> [num_segments, Q] f32.
    """
    if not use_kernel:
        return functools.partial(banded_segsum_ref,
                                 num_segments=num_segments)
    k_max = required_k_max(seg_ids_host, num_segments, s_tile, n_tile)
    if k_max > k_cap:
        # hub-dominated band too wide: XLA scatter path wins
        return functools.partial(banded_segsum_ref,
                                 num_segments=num_segments)
    interp = (not on_tpu()) if interpret is None else interpret

    def fn(values, seg_ids):
        return banded_segsum_pallas(
            values, seg_ids, num_segments=num_segments, k_max=k_max,
            s_tile=s_tile, n_tile=n_tile, interpret=interp)

    return fn
