"""Stepwise (seed) vs device-resident pipelined wave engine.

Measures the tentpole claims head to head on the same engine, same
schedule, same wave width:

  * wall time — the pipelined engine overlaps host pruning bookkeeping
    with device compute and never re-stacks lane buffers;
  * host sync counts — one blocking device_get per step vs 3 + one per
    discovered core;
  * device->host bytes per step — packed uint32 bitmasks (O(W*V/32)
    words) vs per-core [V] bool masks (O(W*V) bytes worst case).

The reference workload is a fixed window of the CPU-scaled collegemsg
analogue (deterministic — no query search loop), chosen to be
dispatch/transfer-bound like the paper's result-proportional regime.
Both modes' result sets are compared core-by-core and the run raises on
any divergence, so ``python -m benchmarks.run`` exits non-zero if the
pipelined engine ever drifts from the seed baseline — the bench doubles
as a regression gate.  Emits rows for
benchmarks/results/bench_pipeline.json; run.py folds the same rows into
the repo-root BENCH_wave.json trajectory file.
"""

from __future__ import annotations

from benchmarks.common import (GRAPH_K, assert_cores_equal, emit, engine,
                               graph, timeit)

SPAN_UTS = 120      # unique timestamps in the reference window
START_UTS = 100     # fixed window start (index into unique_ts)


def reference_window(name: str):
    uts = graph(name).unique_ts
    i0 = min(START_UTS, max(0, uts.size - SPAN_UTS - 1))
    return int(uts[i0]), int(uts[min(i0 + SPAN_UTS, uts.size - 1)])


def run(name: str = "collegemsg", wave: int = 8, repeat: int = 3):
    eng = engine(name)
    k = GRAPH_K[name]
    ts, te = reference_window(name)
    rows = []
    by_mode = {}
    results = {}
    for mode in ("wave_stepwise", "wave"):
        fn = lambda: eng.query(k, ts, te, mode=mode, wave=wave)  # noqa: E731
        res = fn()                       # warm the compile caches
        results[mode] = res
        t = timeit(fn, repeat=repeat)
        s = res.stats
        row = {
            "bench": "pipeline", "graph": name, "mode": mode, "wave": wave,
            "ts": ts, "te": te, "k": k, "t_s": t, "n_cores": len(res),
            "device_steps": s.device_steps, "cells": s.cells_evaluated,
            "duplicates": s.duplicates, "host_syncs": s.host_syncs,
            "bytes_synced": s.bytes_synced,
            "syncs_per_step": s.host_syncs / max(1, s.device_steps),
            "bytes_per_step": s.bytes_synced / max(1, s.device_steps),
            "lane_refills": s.lane_refills, "peel_iters": s.peel_iters,
        }
        rows.append(row)
        by_mode[mode] = row
    # regression gate: the pipelined engine must return exactly the seed
    # stepwise engine's result set on the reference workload — a raise
    # here makes `python -m benchmarks.run` exit non-zero
    assert_cores_equal(results["wave"], results["wave_stepwise"],
                       ctx=f"wave vs wave_stepwise on {name}")
    sw, pl = by_mode["wave_stepwise"], by_mode["wave"]
    rows.append({
        "bench": "pipeline_summary", "graph": name, "wave": wave,
        "equivalent": True,     # the gate above raised otherwise
        "speedup_pipelined_vs_stepwise": sw["t_s"] / pl["t_s"],
        "sync_reduction": sw["host_syncs"] / max(1, pl["host_syncs"]),
        "bytes_per_step_reduction":
            sw["bytes_per_step"] / max(1e-9, pl["bytes_per_step"]),
    })
    emit("bench_pipeline", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
