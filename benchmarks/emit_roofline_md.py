"""Inject the generated §Roofline table into EXPERIMENTS.md (between the
ROOFLINE_TABLE marker and the next heading-levelled prose)."""

import io
import os
import re
import sys
from contextlib import redirect_stdout

HERE = os.path.dirname(__file__)


def main():
    from benchmarks import roofline

    buf = io.StringIO()
    with redirect_stdout(buf):
        roofline.main()
    table = buf.getvalue()
    path = os.path.join(HERE, "..", "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    marker = "<!-- ROOFLINE_TABLE -->"
    start = text.index(marker)
    end = text.index("\nReading the table:", start)
    text = (text[:start + len(marker)] + "\n```\n" + table.rstrip()
            + "\n```\n" + text[end:])
    with open(path, "w") as f:
        f.write(text)
    print(f"injected {len(table.splitlines())} lines into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
