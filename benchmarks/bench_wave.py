"""Beyond-paper engine benches: wave width scaling, Pallas kernel vs XLA
segment-sum degree path, peel-iteration counts, and the fused wave-peel
step (``run_kernel``: bit-identity gate + structured HLO cost-model
deltas fused vs unfused — feeds the roofline's per-iteration cost model
and BENCH_wave.json's ``kernel`` section)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.wave import make_segsum_fns, tcd_wave

from benchmarks.common import GRAPH_K, emit, engine, graph, pick_queries, \
    timeit


def run(name: str = "collegemsg"):
    g = graph(name)
    eng = engine(name)
    k = GRAPH_K[name]
    q = pick_queries(name, 1, span_uts=120, seed=3)[0]
    rows = []
    for wave in (1, 4, 16, 64):
        mode = "serial" if wave == 1 else "wave"
        kw = {} if wave == 1 else {"mode": "wave", "wave": wave}
        t = timeit(lambda: eng.query(k, q["ts"], q["te"], **kw), repeat=2)
        res = eng.query(k, q["ts"], q["te"], **kw)
        rows.append({"bench": "wave_width", "graph": name, "wave": wave,
                     "t_s": t, "device_steps": res.stats.device_steps,
                     "cells": res.stats.cells_evaluated,
                     "n_cores": len(res)})

    # kernel-vs-XLA degree path on a standalone wave
    tel = g.device_tel()
    uts = g.unique_ts
    qn = 16
    rng = np.random.default_rng(0)
    idx = rng.integers(0, uts.size - 10, qn)
    ts = jnp.asarray(uts[idx], jnp.int32)
    te = jnp.asarray(uts[np.minimum(idx + 80, uts.size - 1)], jnp.int32)
    alive = jnp.ones((qn, g.num_vertices), bool)
    for use_kernel, label in ((False, "xla_segsum"), (True, "pallas")):
        sp, sv = make_segsum_fns(g, use_kernel=use_kernel)

        def go():
            r = tcd_wave(tel, alive, ts, te, k, 1,
                         num_vertices=g.num_vertices,
                         seg_pair=sp, seg_vert=sv)
            r.alive.block_until_ready()
            return r

        t = timeit(go, repeat=2)
        r = go()
        rows.append({"bench": "degree_path", "graph": name, "path": label,
                     "t_s": t, "iters": int(r.iters),
                     "note": "pallas runs interpret-mode on CPU; the TPU "
                             "comparison is structural (see EXPERIMENTS)"})
    emit("bench_wave", rows)
    return rows


def analyze_fused_step(name: str = "collegemsg", wave: int = 16,
                       seed: int = 0) -> dict:
    """Fused-Pallas vs XLA-composite wave step on one seeded mixed wave.

    Runs both lowerings (the fused kernel in interpret mode on CPU — the
    same kernel body the TPU compiles) and RAISES on any bit divergence;
    then builds the structural cost comparison: the unfused chain's
    per-iteration HBM bytes/FLOPs from the compiled HLO (launch/hlo_cost
    while-body accounting) vs the fused kernel's analytic model, whose
    HBM bytes are iteration-independent.  Cost numbers are valid on CPU —
    they describe the lowerings, not the host — which is why they (and
    not interpret-mode wall-clock) are the regression gate.
    """
    from repro.core.wave import _wave_step_nodonate, make_wave_step_fn
    from repro.kernels.segdeg.ops import on_tpu
    from repro.kernels.wave_peel.ops import fused_step_cost
    from repro.launch.hlo_cost import HLOCost

    g = graph(name)
    tel = g.device_tel()
    v = g.num_vertices
    e = int(tel.t.shape[0])
    p = int(tel.pair_u.shape[0])
    hp = int(tel.hp_src.shape[0])
    sp, sv = make_segsum_fns(g, use_kernel=False)
    fused = make_wave_step_fn(tel, v, use_kernel=True)
    comp = make_wave_step_fn(tel, v, use_kernel=False,
                             seg_pair=sp, seg_vert=sv)

    rng = np.random.default_rng(seed)
    uts = g.unique_ts
    idx = rng.integers(0, max(1, uts.size - 90), wave)
    ts = jnp.asarray(uts[idx], jnp.int32)
    te = jnp.asarray(uts[np.minimum(idx + 80, uts.size - 1)], jnp.int32)
    k = jnp.asarray(rng.integers(2, 5, wave), jnp.int32)
    h = jnp.asarray(rng.integers(1, 3, wave), jnp.int32)
    alive = jnp.ones((wave, v), dtype=bool)

    def go_fused():
        r = fused(alive, ts, te, k, h)
        r.alive.block_until_ready()
        return r

    def go_comp():
        r = comp(alive, ts, te, k, h)
        r.alive.block_until_ready()
        return r

    t_fused = timeit(go_fused, repeat=2)
    t_comp = timeit(go_comp, repeat=2)
    rf, rc = go_fused(), go_comp()
    for field in ("alive", "packed", "tti_lo", "tti_hi", "n_edges", "iters"):
        a = np.asarray(getattr(rf, field))
        b = np.asarray(getattr(rc, field))
        if not np.array_equal(a, b):
            raise RuntimeError(
                f"fused wave-peel kernel diverges from the XLA composite "
                f"on {field} (graph={name}, seed={seed})")
    iters = int(rf.iters)

    # unfused chain: compiled HLO, while-body per-iteration accounting
    # (the dynamic fixpoint cond has no static trip count, so the module
    # total counts the body once; N iterations add (N-1) x body)
    hlo = _wave_step_nodonate.lower(
        tel, alive, ts, te, k, h, num_vertices=v,
        seg_pair=sp, seg_vert=sv).compile().as_text()
    hc = HLOCost(hlo)
    # only dynamic-condition loops (the fixpoint) scale with iters; their
    # bodies already fold in any nested counted loops (scatter lowerings)
    bodies = [v for v in hc.while_bodies().values() if v["dynamic"]]
    flops_it = sum(b["flops"] for b in bodies)
    bytes_it = sum(b["bytes"] for b in bodies)
    unfused_bytes = hc.bytes + (iters - 1) * bytes_it
    unfused_flops = hc.flops + (iters - 1) * flops_it
    # [W, E] / [E, W] HBM materializations per iteration in the unfused
    # lowering (edge activity + its transposed f32 segsum operand)
    we_census = hc.shape_census((wave, e)) + hc.shape_census((e, wave))

    w_tile = getattr(fused, "w_tile", 8)
    fc = fused_step_cost(e, p, hp, v, wave=wave, w_tile=w_tile, iters=iters)
    # structural [W, E] check on the fused side: the kernel's only HBM
    # operands are the [1, E_pad] tables and the [W_pad, V32] lane slab
    fused_we = sum(1 for s in getattr(fused, "operand_shapes", [])
                   if len(s) == 2 and set(s) == {wave, e} and e != wave)
    if fc["bytes_per_iter_hbm"] > 0:
        fused_we += 1

    return {
        "graph": name, "wave": wave, "iters": iters, "seed": seed,
        "num_edges": e, "num_pairs": p, "num_vertices": v,
        "backend": fused.backend, "interpret": bool(fused.interpret),
        "compiled_tpu": bool(on_tpu()),
        "t_fused_s": t_fused, "t_composite_s": t_comp,
        "unfused_bytes_step": unfused_bytes,
        "unfused_bytes_per_iter": bytes_it,
        "unfused_flops_step": unfused_flops,
        "unfused_we_materializations": we_census,
        "fused_bytes_step": fc["bytes_per_step"],
        "fused_bytes_per_iter_hbm": fc["bytes_per_iter_hbm"],
        "fused_flops_step": fc["flops_per_step"],
        "fused_vmem_bytes": fc["vmem_bytes"],
        "fused_we_materializations": fused_we,
        "bytes_ratio": fc["bytes_per_step"] / max(unfused_bytes, 1.0),
    }


def run_kernel(name: str = "collegemsg") -> list:
    """The fused_step bench + gates.  Raises RuntimeError on fused-vs-
    composite divergence or if the fused lowering's modeled bytes/step is
    not strictly below the unfused chain's.  Interpret-mode wall-clock is
    recorded for context but is explicitly NOT the gate (on CPU the
    kernel runs under the Pallas interpreter; the TPU compiles it)."""
    info = analyze_fused_step(name)
    if not info["fused_bytes_step"] < info["unfused_bytes_step"]:
        raise RuntimeError(
            "fused wave-peel kernel does not win on modeled HBM bytes/step: "
            f"fused={info['fused_bytes_step']:.0f} vs "
            f"unfused={info['unfused_bytes_step']:.0f}")
    if info["unfused_we_materializations"] <= 0:
        raise RuntimeError(
            "unfused-lowering census found no [W, E] HBM materializations — "
            "the cost baseline is not measuring the chain it claims to")
    if info["fused_we_materializations"] != 0:
        raise RuntimeError(
            "fused lowering still materializes [W, E] arrays in HBM")
    note = ("compiled TPU wall-clock" if info["compiled_tpu"] else
            "interpret-mode wall-clock on CPU — context only, NOT the gate")
    rows = [
        {"bench": "fused_step", "graph": name, "path": "fused_pallas",
         "t_s": info["t_fused_s"], "iters": info["iters"],
         "wave": info["wave"], "backend": info["backend"],
         "interpret": info["interpret"], "note": note},
        {"bench": "fused_step", "graph": name, "path": "xla_composite",
         "t_s": info["t_composite_s"], "iters": info["iters"],
         "wave": info["wave"], "backend": "xla", "interpret": False,
         "note": "XLA wall-clock on the current host"},
        dict(info, bench="fused_step_cost",
             gate="bit-identity + fused_bytes_step < unfused_bytes_step",
             gate_ok=True),
    ]
    emit("bench_kernel", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
    for r in run_kernel():
        print(r)
