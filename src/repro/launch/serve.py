"""TCQ serving launcher: the paper's system as a *streaming service* —
open-loop query arrivals over a temporal graph that keeps growing while
queries run, served by ``TCQService`` (window-clustered lane pools,
mid-flight admission, epoch-pinned snapshots).

    PYTHONPATH=src python -m repro.launch.serve --vertices 2000 \
        --edges 30000 --requests 16 --qps 4 [--ingest-batches 4] \
        [--distributed] [--combine rs_ag]

The driver is open-loop: request arrival times come from a seeded
exponential inter-arrival process at ``--qps`` and are injected by the
service's ``poll`` hook whenever lanes free up — arrivals during a pool
run are admitted mid-flight when their window fits, otherwise they queue
for the next pool.  Edge ingestion batches land on their own schedule
(between arrivals), each producing a new TEL epoch; queries always
answer over the snapshot current at their admission.  Reported: p50 /
p95 / p99 submit-to-completion latency, sustained qps, mean pool
occupancy, and the epoch count ingested while serving.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

# --fake-devices must take effect before jax picks its host backend, so
# scan argv at import time (argparse runs far too late: any repro import
# below main() may initialize jax).
if "--fake-devices" in sys.argv:
    _n = int(sys.argv[sys.argv.index("--fake-devices") + 1])
    if _n > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={_n}").strip()

import numpy as np


# -------------------------------------------------------------- backpressure
class Backpressure:
    """Admission control in front of ``TCQService.submit``: a bounded
    request queue with a qps ceiling and a shed-oldest-past-deadline
    policy.

    ``offer`` is the only entry point — it either admits the request
    (returning its ticket) or sheds it (returning None, counted).  Three
    gates, in order:

    1. **qps ceiling** — a token bucket refilled at ``qps_ceiling``
       (burst = ``queue_cap``); an empty bucket sheds the arrival
       outright (the HTTP-429 analogue).
    2. **deadline stamping** — admitted requests without their own
       ``deadline_s`` inherit ``deadline_s`` (None = best-effort).
    3. **bounded queue** — when the service backlog is at ``queue_cap``,
       queued tickets already past their deadline are timed out first
       (shed-oldest-past-deadline: they could never answer in time, so
       they yield their slot); if the backlog is still full, the arrival
       itself is shed.

    Shed rate = ``shed / offered`` — the closed-loop driver reports it
    alongside latency percentiles, because under overload a low p99 is
    meaningless without the fraction of traffic it was bought with.
    """

    def __init__(self, svc, *, queue_cap: int = 64,
                 qps_ceiling: float = 0.0, deadline_s: float = 0.0):
        self.svc = svc
        self.queue_cap = int(queue_cap)
        self.qps_ceiling = float(qps_ceiling or 0.0)
        self.deadline_s = float(deadline_s or 0.0)
        self.offered = 0
        self.shed = 0
        self.timeouts_swept = 0
        self._tokens = float(queue_cap)
        self._last = time.perf_counter()

    def offer(self, request):
        """Admit ``request`` or shed it; returns the ticket or None."""
        self.offered += 1
        now = time.perf_counter()
        if self.qps_ceiling > 0.0:
            self._tokens = min(float(self.queue_cap), self._tokens
                               + (now - self._last) * self.qps_ceiling)
            self._last = now
            if self._tokens < 1.0:
                self.shed += 1
                return None
            self._tokens -= 1.0
        if self.svc.pending >= self.queue_cap:
            self.timeouts_swept += len(self.svc.expire(now))
            if self.svc.pending >= self.queue_cap:
                self.shed += 1
                return None
        r = dict(request)
        if self.deadline_s > 0.0:
            r.setdefault("deadline_s", self.deadline_s)
        return self.svc.submit(r)

    @property
    def shed_rate(self) -> float:
        return self.shed / max(1, self.offered)


def _cache_report(stats) -> str:
    """One report line from ``TCQService.stats``: window-TEL LRU counters
    plus (when result caching is on) TTI core-cache hit rate and size."""
    wt = stats["window_tel"]
    line = (f"[serve] window-TEL LRU: {wt['hits']} hits / "
            f"{wt['misses']} misses / {wt['evictions']} evictions "
            f"({wt['size']} live)")
    cc = stats.get("core_cache")
    if cc is None:
        return line + " | core cache: off"
    return line + (f" | core cache: {cc['hits'] + cc['dominance_hits']} "
                   f"hits ({cc['hit_rate']:.1%}, {cc['dominance_hits']} by "
                   f"dominance), {cc['invalidated']} invalidated, "
                   f"{cc['rekeyed']} re-keyed, "
                   f"{cc['n_cores']} cores / {cc['bytes'] / 1024:.1f} KiB"
                   + (f", {stats['prewarmed']} prewarmed"
                      if stats.get("prewarmed") else ""))


def serve_closed_loop(graph, requests, *, concurrency: int = 8,
                      queue_cap: int = 16, qps_ceiling: float = 0.0,
                      deadline_s: float = 0.0, wave="auto", depth: int = 2,
                      cluster_gap: int = 0, resilience=None, cache=True):
    """Closed-loop driver: keep ``concurrency`` requests outstanding,
    offering the next one the moment a slot frees — the standard way to
    overload a service deterministically (offered load = concurrency /
    service time, no arrival clock to race).  Requests flow through a
    :class:`Backpressure` gate, so overload shows up as shed traffic and
    deadline timeouts rather than an unbounded queue.

    Returns ``(svc, tickets, report)`` where ``report`` carries offered /
    shed / timeout counts, shed rate, completed-qps and p50/p95/p99
    latency of *completed* requests.
    """
    from repro.core import TCQService

    svc = TCQService(graph, wave=wave, depth=depth, cluster_gap=cluster_gap,
                     retain_snapshots=False, resilience=resilience,
                     cache=cache)
    bp = Backpressure(svc, queue_cap=queue_cap, qps_ceiling=qps_ceiling,
                      deadline_s=deadline_s)
    queue = list(requests)
    tickets = []
    state = {"i": 0}

    def outstanding() -> int:
        return sum(1 for tk in tickets if not tk.done)

    def poll(s):
        # at most one offer per poll tick (pool formation / lanes
        # freeing): the closed loop reacts to service progress instead
        # of dumping its whole queue into the shedder in one burst
        if state["i"] < len(queue) and outstanding() < concurrency:
            tk = bp.offer(queue[state["i"]])
            state["i"] += 1
            if tk is not None:
                tickets.append(tk)

    t0 = time.perf_counter()
    while True:
        svc.run_until_idle(poll)
        if state["i"] >= len(queue) and not svc.pending:
            break
        # shed-everything stall guard: let the token bucket refill
        time.sleep(0.002)
    wall = time.perf_counter() - t0

    done = [tk for tk in tickets if tk.status == "done"]
    lat = np.array([tk.latency_s for tk in done]) if done else np.array([0.0])
    report = {
        "offered": bp.offered,
        "admitted": len(tickets),
        "shed": bp.shed,
        "shed_rate": bp.shed_rate,
        "timeouts": sum(tk.status == "timeout" for tk in tickets),
        "completed": len(done),
        "qps": len(done) / wall if wall > 0 else 0.0,
        "p50_ms": 1e3 * float(np.quantile(lat, .50)),
        "p95_ms": 1e3 * float(np.quantile(lat, .95)),
        "p99_ms": 1e3 * float(np.quantile(lat, .99)),
        "wall_s": wall,
        "cache": svc.stats,     # window-TEL LRU + TTI core-cache counters
    }
    return svc, tickets, report


def serve_stream(graph, requests, *, qps: float, ingest=None,
                 wave="auto", depth: int = 2, cluster_gap: int = 0,
                 warm: bool = True, cache=True, prewarm: int = 0,
                 wal_dir=None, fsync: str = "batch", svc=None):
    """Drive a TCQService with an open-loop arrival schedule.

    ``requests`` is a list of dicts with an ``arrive_s`` offset
    (``TCQRequestStream.open_loop`` format); ``ingest`` is an optional
    iterator of (u, v, t) arrival batches pushed one per poll interval.
    ``prewarm`` > 0 peels up to that many of the hottest observed windows
    into the TTI core cache whenever the driver goes idle between
    arrivals (``TCQService.prewarm``) — idle lanes buy warm hits for the
    recurring traffic.  ``wal_dir``/``fsync`` attach a write-ahead
    journal so every admission and ingest batch survives a crash
    (``TCQService.recover``); pass a pre-built ``svc`` (e.g. one that
    was just recovered) to drive it instead of constructing a fresh
    service.  Returns (service, served tickets, wall seconds).
    """
    from repro.core import TCQService

    if svc is None:
        # retain_snapshots=False: a long-lived server must not keep one
        # O(E) graph snapshot alive per ingested epoch through its
        # ticket history
        svc = TCQService(graph, wave=wave, depth=depth,
                         cluster_gap=cluster_gap, retain_snapshots=False,
                         cache=cache, wal_dir=wal_dir, fsync=fsync)
    if warm and requests:
        # warm the compile caches so latency percentiles measure the
        # steady state, not first-shape compilation
        r0 = requests[0]
        svc.submit({k: r0[k] for k in ("k", "ts", "te")})
        svc.run_until_idle()
        svc.completed.clear()
        svc.pool_log.clear()
    queue = sorted(requests, key=lambda r: r["arrive_s"])
    ingest = iter(ingest) if ingest is not None else None
    state = {"i": 0, "epochs": 0, "t0": time.perf_counter()}

    def poll(s):
        now = time.perf_counter() - state["t0"]
        while state["i"] < len(queue) and queue[state["i"]]["arrive_s"] <= now:
            s.submit(queue[state["i"]])
            state["i"] += 1
        if ingest is not None and state["epochs"] < state["i"]:
            # one ingestion batch per served arrival tranche: edges land
            # continuously while queries are in flight
            try:
                u, v, t = next(ingest)
                s.push_edges(u, v, t)
                state["epochs"] += 1
            except StopIteration:
                pass

    served = []
    while state["i"] < len(queue) or svc.pending:
        out = svc.run_until_idle(poll)
        served.extend(out)
        if state["i"] < len(queue):
            # idle before the next arrival: spend the gap prewarming the
            # hottest windows, then sleep to the arrival time
            if prewarm > 0:
                svc.prewarm(prewarm)
            nxt = queue[state["i"]]["arrive_s"] - (
                time.perf_counter() - state["t0"])
            if nxt > 0:
                time.sleep(min(nxt, 0.05))
    wall = time.perf_counter() - state["t0"]
    return svc, served, wall


def serve_distributed(graph, requests, *, mesh, combine="auto",
                      controllers: int = 1, wave="auto", depth: int = 2,
                      cache: bool = False, warm: bool = True):
    """Multi-controller open-loop driver over the sharded engine.

    ``controllers`` independent arrival processes (the open-loop request
    list partitioned round-robin, each keeping its own arrival clock) are
    interleaved into one pump loop — ``TCQService`` is single-writer, so
    the controllers multiplex submissions rather than run threads, which
    is exactly the multi-controller shape of a shard_map program: one
    Python process per host driving a slice of the arrival load against
    the same mesh-spanning lane pool.

    Returns ``(svc, served, report)``; ``report`` carries aggregate and
    per-controller qps / p50 / p95 / p99 plus the mesh shape, combine
    strategy, per-shard lane occupancy and combine-collective bytes.
    """
    from repro.core import TCQService

    svc = TCQService(graph, wave=wave, depth=depth, retain_snapshots=False,
                     cache=cache, mesh=mesh, combine=combine)
    if warm and requests:
        r0 = requests[0]
        svc.submit({k: r0[k] for k in ("k", "ts", "te")})
        svc.run_until_idle()
        svc.completed.clear()
        svc.pool_log.clear()
    n = max(1, int(controllers))
    lanes = [sorted((r for j, r in enumerate(requests) if j % n == c),
                    key=lambda r: r["arrive_s"]) for c in range(n)]
    owner = {}
    state = {"i": [0] * n, "t0": time.perf_counter()}

    def poll(s):
        now = time.perf_counter() - state["t0"]
        for c in range(n):
            q, i = lanes[c], state["i"][c]
            while i < len(q) and q[i]["arrive_s"] <= now:
                tk = s.submit(q[i])
                owner[tk.id] = c
                i += 1
            state["i"][c] = i

    served = []
    while any(state["i"][c] < len(lanes[c]) for c in range(n)) or svc.pending:
        served.extend(svc.run_until_idle(poll))
        nxt = min((lanes[c][state["i"][c]]["arrive_s"]
                   for c in range(n) if state["i"][c] < len(lanes[c])),
                  default=None)
        if nxt is not None:
            gap = nxt - (time.perf_counter() - state["t0"])
            if gap > 0:
                time.sleep(min(gap, 0.05))
    wall = time.perf_counter() - state["t0"]

    def _pcts(tks):
        lat = (np.array([tk.latency_s for tk in tks]) if tks
               else np.array([0.0]))
        return {"completed": len(tks),
                "qps": len(tks) / wall if wall > 0 else 0.0,
                "p50_ms": 1e3 * float(np.quantile(lat, .50)),
                "p95_ms": 1e3 * float(np.quantile(lat, .95)),
                "p99_ms": 1e3 * float(np.quantile(lat, .99))}

    per = [dict(controller=c,
                **_pcts([tk for tk in served if owner.get(tk.id) == c]))
           for c in range(n)]
    dist = svc.stats["distributed"]
    occ = [p["shard_occupancy"] for p in svc.pool_log
           if p.get("shard_occupancy")]
    report = dict(_pcts(served))
    report.update({
        "controllers": per,
        "wall_s": wall,
        "mesh": dist["mesh"],
        "combine": dist["combine"],
        "collective_bytes": dist["collective_bytes"],
        "shard_occupancy": ([float(x) for x in np.mean(occ, axis=0)]
                            if occ else []),
    })
    return svc, served, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=2_000)
    ap.add_argument("--edges", type=int, default=30_000)
    ap.add_argument("--span", type=int, default=16_384)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--qps", type=float, default=4.0,
                    help="open-loop arrival rate (requests/sec)")
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--wave", default="auto")
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--ingest-batches", type=int, default=4,
                    help="edge arrival batches streamed during serving")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request deadline in seconds from submission; "
                         "requests past it are timed out mid-pool with "
                         "partial results (0 = best-effort, no deadline)")
    ap.add_argument("--queue-cap", type=int, default=64,
                    help="bounded admission queue depth; at capacity, "
                         "queued requests past their deadline are shed "
                         "first, then new arrivals are shed")
    ap.add_argument("--qps-ceiling", type=float, default=0.0,
                    help="admission rate ceiling (token bucket); arrivals "
                         "above it are shed outright (0 = unlimited)")
    ap.add_argument("--closed-loop", action="store_true",
                    help="closed-loop driver: keep --concurrency requests "
                         "outstanding (deterministic overload) instead of "
                         "the open-loop arrival clock; reports shed rate "
                         "alongside latency percentiles")
    ap.add_argument("--concurrency", type=int, default=8,
                    help="outstanding requests in --closed-loop mode")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the TTI-keyed core-result cache "
                         "(every request recomputes from scratch)")
    ap.add_argument("--prewarm", type=int, default=0,
                    help="open-loop mode: peel up to N of the hottest "
                         "observed windows into the core cache whenever "
                         "the driver idles between arrivals (0 = off)")
    ap.add_argument("--distributed", action="store_true",
                    help="shard_map engine on the local host mesh")
    ap.add_argument("--combine", default="auto",
                    choices=["auto", "psum", "rs_ag"])
    ap.add_argument("--fake-devices", type=int, default=0,
                    help="force N virtual host devices (must be set before "
                         "jax initializes; handled at module import)")
    ap.add_argument("--model-shards", type=int, default=1,
                    help="devices along the model (edge-sharding) axis; "
                         "the rest go to the lane axis")
    ap.add_argument("--controllers", type=int, default=1,
                    help="interleaved open-loop arrival processes in "
                         "--distributed mode")
    ap.add_argument("--wal-dir", default=None,
                    help="write-ahead journal directory: every admission "
                         "and ingest batch is logged before it is applied; "
                         "on start, an existing journal is recovered "
                         "(newest valid snapshot + tail replay) and served "
                         "from, and a checkpoint is written on clean exit")
    ap.add_argument("--fsync", default="batch",
                    choices=["always", "batch", "off"],
                    help="journal flush policy: 'always' fsyncs every "
                         "record (no acknowledged op can be lost), "
                         "'batch' fsyncs at pump boundaries (bounded loss "
                         "on power failure only), 'off' leaves flushing "
                         "to the OS")
    args = ap.parse_args()

    from repro.data import TCQRequestStream
    from repro.graphs import EdgeStream, powerlaw_temporal

    g = powerlaw_temporal(args.vertices, args.edges, args.span, seed=3)
    lo, hi = g.span

    wave = args.wave if args.wave == "auto" else int(args.wave)

    if args.distributed:
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(args.model_shards)
        reqs = list(TCQRequestStream(lo, hi, k=args.k,
                                     span=max(64, args.span // 20),
                                     seed=0).open_loop(args.requests,
                                                       args.qps))
        svc, served, rep = serve_distributed(
            g, reqs, mesh=mesh, combine=args.combine,
            controllers=args.controllers, wave=wave, depth=args.depth,
            cache=not args.no_cache)
        print(f"[serve] distributed: {rep['completed']} requests in "
              f"{rep['wall_s']:.2f}s ({rep['qps']:.2f} qps aggregate) on "
              f"mesh {rep['mesh']} (combine={rep['combine']})")
        print(f"[serve] latency p50 {rep['p50_ms']:.1f} ms | "
              f"p95 {rep['p95_ms']:.1f} ms | p99 {rep['p99_ms']:.1f} ms")
        for c in rep["controllers"]:
            print(f"[serve]   controller#{c['controller']}: "
                  f"{c['completed']} done, {c['qps']:.2f} qps, "
                  f"p50 {c['p50_ms']:.1f} / p95 {c['p95_ms']:.1f} / "
                  f"p99 {c['p99_ms']:.1f} ms")
        occ = ", ".join(f"{x:.2f}" for x in rep["shard_occupancy"])
        print(f"[serve] per-shard lane occupancy [{occ}], "
              f"{rep['collective_bytes']} combine-collective bytes")
        return

    if args.closed_loop:
        reqs = list(TCQRequestStream(lo, hi, k=args.k,
                                     span=max(64, args.span // 20),
                                     seed=0).requests(args.requests))
        svc, tickets, rep = serve_closed_loop(
            g, reqs, concurrency=args.concurrency,
            queue_cap=args.queue_cap, qps_ceiling=args.qps_ceiling,
            deadline_s=args.deadline_s, wave=wave, depth=args.depth,
            cache=not args.no_cache)
        print(f"[serve] closed loop: {rep['offered']} offered, "
              f"{rep['completed']} completed in {rep['wall_s']:.2f}s "
              f"({rep['qps']:.2f} qps), {rep['shed']} shed "
              f"(rate {rep['shed_rate']:.2%}), {rep['timeouts']} timeouts")
        print(f"[serve] latency p50 {rep['p50_ms']:.1f} ms | "
              f"p95 {rep['p95_ms']:.1f} ms | p99 {rep['p99_ms']:.1f} ms")
        print(_cache_report(rep["cache"]))
        return

    reqs = list(TCQRequestStream(lo, hi, k=args.k,
                                 span=max(64, args.span // 20),
                                 seed=0).open_loop(args.requests, args.qps))
    if args.deadline_s > 0.0:
        for r in reqs:
            r["deadline_s"] = args.deadline_s
    future = powerlaw_temporal(args.vertices, max(args.edges // 8, 64),
                               args.span // 4, seed=5)
    arrivals = ((u, v, t + hi) for u, v, t in
                EdgeStream.replay(future, max(1, args.ingest_batches)))

    svc = None
    if args.wal_dir is not None:
        from repro.core import TCQService
        from repro.core.wal import list_snapshots

        if list_snapshots(args.wal_dir):
            # recovery-on-start: pick up exactly where the previous
            # process died — queued tickets drain first, then new traffic
            svc = TCQService.recover(args.wal_dir, fsync=args.fsync,
                                     wave=wave, depth=args.depth,
                                     retain_snapshots=False,
                                     cache=not args.no_cache)
            rr = svc.recovery_report
            print(f"[serve] recovered from {rr['snapshot']} + "
                  f"{rr['wal_records']} journal records in "
                  f"{1e3 * rr['recover_s']:.1f} ms "
                  f"({rr['pending_after']} tickets re-queued, epoch "
                  f"{rr['epoch_after']}"
                  + (f", {len(rr['tail_events'])} torn/corrupt tail "
                     f"records cut" if rr["tail_events"] else "")
                  + (f", {len(rr['snapshots_skipped'])} corrupt "
                     f"snapshots skipped" if rr["snapshots_skipped"]
                     else "") + ")")

    svc, served, wall = serve_stream(g, reqs, qps=args.qps, ingest=arrivals,
                                     wave=wave, depth=args.depth,
                                     cache=not args.no_cache,
                                     prewarm=args.prewarm,
                                     wal_dir=args.wal_dir, fsync=args.fsync,
                                     svc=svc)
    lat = np.array([tk.latency_s for tk in served])
    occ = [p["occupancy"] for p in svc.pool_log if p["device_steps"]]
    mid = sum(p["admitted_midflight"] for p in svc.pool_log)
    for tk in sorted(served, key=lambda tk: tk.id)[:8]:
        print(f"req#{tk.id:03d} k={tk.k} window=[{tk.ts},{tk.te}] "
              f"epoch={tk.epoch} -> {len(tk.result)} cores "
              f"({1e3 * tk.latency_s:.1f} ms)")
    print(f"\n[serve] {len(served)} requests in {wall:.2f}s "
          f"({len(served) / wall:.2f} qps sustained, target {args.qps}) "
          f"over {svc.epoch} ingested epochs")
    print(f"[serve] latency p50 {1e3 * np.quantile(lat, .5):.1f} ms | "
          f"p95 {1e3 * np.quantile(lat, .95):.1f} ms | "
          f"p99 {1e3 * np.quantile(lat, .99):.1f} ms")
    print(f"[serve] {len(svc.pool_log)} pools, "
          f"mean occupancy {np.mean(occ) if occ else 0:.1f} cells/step, "
          f"{mid} mid-flight admissions, "
          f"{sum(tk.status == 'timeout' for tk in served)} deadline timeouts")
    print(_cache_report(svc.stats))
    if svc.wal is not None:
        ck = svc.checkpoint()
        ws = svc.wal.stats()
        print(f"[serve] journal: {ws['records_appended']} records / "
              f"{ws['bytes_appended']} bytes appended "
              f"(fsync={ws['fsync']}, {ws['syncs']} syncs); clean-exit "
              f"checkpoint seq {ck['wal_seq']} in "
              f"{1e3 * ck['checkpoint_s']:.1f} ms "
              f"({ck['gc_removed']} files GC'd)")


if __name__ == "__main__":
    main()
