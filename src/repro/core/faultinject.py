"""Seeded fault injection for the TCQ serving stack (chaos harness).

Faults are injected at the *wave-step seam*: every engine backend — fused
Pallas kernel, XLA composite, numpy oracle — is a step closure with the
same signature, and the degradation ladder
(:class:`repro.core.wave.DegradationLadder`) already wraps each rung via
``ResilienceConfig.rung_wrapper``.  :func:`rung_faults` builds such a
wrapper from per-rung :class:`FaultPlan`\\ s, so a chaos scenario is just
an engine constructed with ``resilience=ResilienceConfig(rung_wrapper=
rung_faults({"pallas": FaultPlan(fail_at=(0,))}))`` — no test-only hooks
inside the engine itself.

Everything is keyed by a deterministic per-rung *call counter* (never
wall clock or RNG state shared with the engine), so a scenario replays
bit-identically: the same calls fail, stall, or corrupt on every run.

Fault classes:

* ``fail_at`` — the step raises :class:`KernelFault` (models a compile
  failure, an XLA runtime abort, a device OOM).  The ladder demotes to
  the next rung and replays the same inputs.
* ``slow_at`` — the step sleeps ``delay_s`` before running (models a
  straggler lane / a thermally throttled device).  Results are
  unaffected; only latency moves.
* ``corrupt_at`` — the step's result comes back with the alive-mask of
  every lane flipped at ``corrupt_vertex`` (models silent data
  corruption).  The ladder's sampled oracle tripwire is the only thing
  standing between this and a wrong answer.

:func:`malformed_batches` supplies ingest batches that must be rejected
by ``TemporalGraph``'s validation (:class:`~repro.core.graph.
GraphIngestError`) without perturbing the graph.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, List, Mapping, Optional, Tuple

import numpy as np


class KernelFault(RuntimeError):
    """Injected kernel failure (stands in for compile/runtime/OOM errors)."""


# ---------------------------------------------------------------- fault plan
@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic fault schedule for one ladder rung, keyed by the
    rung's 0-based call counter."""

    fail_at: Tuple[int, ...] = ()       # calls that raise KernelFault
    slow_at: Tuple[int, ...] = ()       # calls delayed by ``delay_s``
    corrupt_at: Tuple[int, ...] = ()    # calls whose alive-mask is flipped
    delay_s: float = 0.05
    corrupt_vertex: int = 0


class FaultyStep:
    """Wrap a wave step closure with a :class:`FaultPlan`.

    Transparent otherwise: attribute reads (``backend``, ``interpret``,
    ``events``) fall through to the wrapped step, so the ladder — and the
    engine's logging — see the rung they expect.
    """

    def __init__(self, fn: Callable, plan: FaultPlan):
        self._fn = fn
        self._plan = plan
        self.calls = 0

    def __getattr__(self, name):
        return getattr(self._fn, name)

    def __call__(self, *args, **kwargs):
        i = self.calls
        self.calls += 1
        plan = self._plan
        if i in plan.fail_at:
            raise KernelFault(f"injected kernel failure (call {i})")
        if i in plan.slow_at:
            time.sleep(plan.delay_s)
        res = self._fn(*args, **kwargs)
        if i in plan.corrupt_at:
            vtx = plan.corrupt_vertex
            # flip every lane's alive bit at one vertex: guaranteed to
            # differ from truth whichever lane the tripwire samples
            res = res._replace(
                alive=res.alive.at[:, vtx].set(~res.alive[:, vtx]))
        return res


def rung_faults(plans: Mapping[str, FaultPlan]
                ) -> Callable[[str, Callable], Callable]:
    """``ResilienceConfig.rung_wrapper`` injecting per-rung fault plans.

    ``plans`` maps rung names (``"pallas"``, ``"xla"``, ``"oracle"``) to
    their schedules; unplanned rungs pass through unwrapped.  Injecting
    into ``"oracle"`` is allowed but note the ladder re-raises once its
    last rung fails.
    """
    def wrapper(name: str, fn: Callable) -> Callable:
        plan = plans.get(name)
        return fn if plan is None else FaultyStep(fn, plan)
    return wrapper


# ------------------------------------------------------- durability injectors
class InjectedCrash(BaseException):
    """A simulated process death at an exact journal point.

    Deliberately a ``BaseException``: service code that caught
    ``Exception`` to degrade gracefully would otherwise swallow the
    "kill" and keep running past the point the drill meant to stop at —
    a real ``kill -9`` is not catchable either.
    """


class CrashingWAL:
    """Wrap a :class:`~repro.core.wal.WriteAheadLog` so the process
    "dies" at a chosen journal point (the kill-anywhere drill's knife).

    ``crash_after_records=n`` raises :class:`InjectedCrash` *after* the
    n-th successful append (0-based: ``0`` dies right after the first
    record lands) — the record is on disk, its acknowledgement never
    happened, exactly the torn-world a mid-operation kill leaves.
    ``crash_on_rotate=True`` dies after the rotation seals the old
    segment but *before* the caller writes its snapshot — the
    checkpoint's worst-case ordering.  ``mutilate`` (called with the
    journal directory) runs post-mortem damage — truncation, bit flips —
    before the drill hands the directory to ``recover``.

    Everything else proxies to the wrapped log, so the service under
    test is byte-for-byte the production code path.
    """

    def __init__(self, inner, *, crash_after_records: Optional[int] = None,
                 crash_on_rotate: bool = False,
                 mutilate: Optional[Callable[[str], None]] = None):
        self._inner = inner
        self._crash_after = crash_after_records
        self._crash_on_rotate = crash_on_rotate
        self._mutilate = mutilate

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def _die(self, where: str):
        if self._mutilate is not None:
            self._inner.close()
            self._mutilate(self._inner.dir)
        raise InjectedCrash(f"injected crash {where}")

    def append(self, kind, meta=None, arrays=None) -> int:
        idx = self._inner.append(kind, meta, arrays)
        if self._crash_after is not None and idx >= self._crash_after:
            self._die(f"after journal record {idx}")
        return idx

    def rotate(self) -> int:
        seq = self._inner.rotate()
        if self._crash_on_rotate:
            self._die(f"after segment rotation to {seq} (pre-snapshot)")
        return seq


def torn_tail(wal_dir: str, nbytes: int = 5) -> str:
    """Post-mortem torn write: chop ``nbytes`` off the newest journal
    segment's tail (models a partial page flush at power loss).  Returns
    the mutilated path."""
    from repro.core.wal import list_segments

    seq, path = list_segments(wal_dir)[-1]
    size = max(0, os.path.getsize(path) - int(nbytes))
    with open(path, "r+b") as f:
        f.truncate(size)
    return path


def flip_tail_byte(wal_dir: str, offset_from_end: int = 3) -> str:
    """Post-mortem bit rot: XOR one byte near the newest segment's tail
    (CRC must catch it — a flipped record is corrupt, not just short)."""
    from repro.core.wal import list_segments

    seq, path = list_segments(wal_dir)[-1]
    size = os.path.getsize(path)
    pos = max(0, size - int(offset_from_end))
    with open(path, "r+b") as f:
        f.seek(pos)
        b = f.read(1) or b"\0"
        f.seek(pos)
        f.write(bytes([b[0] ^ 0xFF]))
    return path


def corrupt_snapshot(wal_dir: str, offset: int = 256) -> str:
    """Post-mortem snapshot damage: XOR one byte of the *newest*
    snapshot file, so its embedded checksum fails and recovery must fall
    back to the previous retained snapshot."""
    from repro.core.wal import list_snapshots

    seq, path = list_snapshots(wal_dir)[-1]
    pos = min(int(offset), os.path.getsize(path) - 1)
    with open(path, "r+b") as f:
        f.seek(pos)
        b = f.read(1)
        f.seek(pos)
        f.write(bytes([b[0] ^ 0xFF]))
    return path


# ---------------------------------------------------------- malformed ingest
def malformed_batches(seed: int = 0
                      ) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Ingest batches that ``TemporalGraph.add_edges`` must reject with
    :class:`~repro.core.graph.GraphIngestError` — one per validation
    class, seeded order."""
    i32 = np.iinfo(np.int32)
    batches = [
        # negative vertex id
        (np.array([-1, 2]), np.array([3, 4]), np.array([5, 6])),
        # fractional float id
        (np.array([1.5, 2.0]), np.array([3.0, 4.0]), np.array([5.0, 6.0])),
        # NaN timestamp
        (np.array([1, 2]), np.array([3, 4]), np.array([np.nan, 6.0])),
        # shape mismatch
        (np.array([1, 2, 3]), np.array([3, 4]), np.array([5, 6])),
        # id overflows the int32 pair-key packing
        (np.array([1 << 40, 2]), np.array([3, 4]), np.array([5, 6])),
        # timestamp collides with the int32-min padding sentinel
        (np.array([1, 2]), np.array([3, 4]), np.array([i32.min, 6])),
        # non-numeric dtype
        (np.array(["a", "b"]), np.array([3, 4]), np.array([5, 6])),
    ]
    rng = np.random.default_rng(seed)
    rng.shuffle(batches)
    return batches
