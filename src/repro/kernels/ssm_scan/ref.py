"""Pure-jnp oracle for the diagonal SSM scan kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(log_a: jnp.ndarray, bx: jnp.ndarray,
                 s0: jnp.ndarray) -> jnp.ndarray:
    """s_t = exp(log_a_t) * s_{t-1} + bx_t, returning all states.

    log_a/bx: [B, S, F] (<= 0 decays); s0: [B, F].  Out: [B, S, F]."""
    def step(carry, xs):
        la, b = xs
        new = jnp.exp(la) * carry + b
        return new, new

    _, ys = jax.lax.scan(step, s0, (log_a.swapaxes(0, 1),
                                    bx.swapaxes(0, 1)))
    return ys.swapaxes(0, 1)
