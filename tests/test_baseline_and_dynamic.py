"""iPHC baseline correctness + dynamic-graph (§6.1) behaviour."""

import numpy as np
import pytest

from repro.core import (PHCIndex, TCQEngine, TemporalGraph,
                        brute_force_query, iphc_query)
from repro.graphs import EdgeStream, paper_style_example, planted_cores


@pytest.mark.parametrize("seed", [0, 3])
def test_iphc_matches_oracle(seed):
    g = planted_cores(seed=seed, num_vertices=32, n_cliques=3,
                      clique_size=5, time_span=20, noise_edges=60)
    k, Ts, Te = 3, 1, 20
    idx = PHCIndex(g, k, Ts, Te)
    res = iphc_query(g, idx, k, Ts, Te)
    oracle = brute_force_query(g, k, Ts, Te)
    assert set(c.tti for c in res.cores) == set(oracle.keys())
    for c in res.cores:
        assert set(c.vertices.tolist()) == set(oracle[c.tti]["vertices"])
        assert c.n_edges == oracle[c.tti]["n_edges"]


def test_phc_index_size_vs_tel():
    """The paper's point: the index dwarfs the TEL it indexes."""
    g = planted_cores(seed=1)
    idx = PHCIndex(g, 3, 1, 40)
    assert idx.nbytes() > g.memory_bytes()


def test_dynamic_append_equals_rebuild():
    g0 = paper_style_example()
    extra = [(3, 6, 9), (5, 6, 9), (3, 5, 9), (0, 4, 10)]
    g1 = g0.add_edges(*zip(*extra))
    g2 = TemporalGraph.from_edge_list(
        list(zip(g0.src, g0.dst, g0.t)) + extra, num_vertices=9)
    assert g1.num_edges == g2.num_edges
    r1 = TCQEngine(g1).query(2, 1, 10)
    r2 = TCQEngine(g2).query(2, 1, 10)
    assert r1.by_tti().keys() == r2.by_tti().keys()


def test_stream_queries_see_new_cores():
    """Serving loop pattern: push arrival batches, re-query, watch the
    result set grow — the paper's dynamic-graph scenario."""
    g = paper_style_example()
    stream = EdgeStream()
    sizes = []
    for u, v, t in EdgeStream.replay(g, 4):
        stream.push(u, v, t)
        res = TCQEngine(stream.graph).query(2, 1, 8)
        sizes.append(len(res))
        oracle = brute_force_query(stream.graph, 2, 1, 8)
        assert set(c.tti for c in res.cores) == set(oracle.keys())
    assert sizes[-1] >= sizes[0]
    assert sizes[-1] == 16  # full graph's distinct 2-cores


def test_out_of_order_arrival():
    """Late edges (timestamps before the current max) are accepted — a
    strict superset of the paper's append-only assumption."""
    g = paper_style_example()
    late = g.add_edges([0], [4], [2])
    oracle = brute_force_query(late, 2, 1, 8)
    res = TCQEngine(late).query(2, 1, 8)
    assert set(c.tti for c in res.cores) == set(oracle.keys())
