"""IBM Granite-3.0 1B-a400m MoE base [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L, d=1024, 16 heads (GQA kv=8), 32 experts top-8 with d_expert=512.
"""
from repro.models.config import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49_155,
    act="silu", glu=True, pos="rope", rope_theta=10_000.0,
    tie_embeddings=True,
    moe=MoECfg(num_experts=32, top_k=8, d_expert=512, every=1),
    max_seq=32_768,
    notes="fine-grained experts (32e top-8); full attention => long_500k skipped",
)
