"""Qwen2 7B [arXiv:2407.10671] — GQA kv=4 with QKV bias."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18_944, vocab=152_064,
    act="silu", glu=True, pos="rope", rope_theta=1_000_000.0, qkv_bias=True,
    tie_embeddings=False,
    max_seq=32_768,
)
