"""Gradient compression: int8 quantized all-reduce with error feedback.

At 1000-node scale the data-parallel gradient all-reduce is the dominant
cross-pod collective; quantizing it to int8 cuts that traffic 4x (bf16) at
<1% quality cost when paired with error feedback (the residual between the
true and quantized gradient is carried into the next step — Seide et al.,
1-bit SGD lineage).

``compressed_psum`` is shard_map-native: it quantizes per-shard, psums the
int32-accumulated payload, and dequantizes with a psum'd per-tensor scale.
The pure-DP trainer (runtime/trainer.py, small-model path) wires it in; at
FSDP/TP scale the same primitive applies to the `pod` axis all-reduce.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(x: jnp.ndarray, axis_name: str,
                    error: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8 all-reduce with error feedback, inside shard_map.

    x: local gradient shard; error: local residual carried from the last
    step (same shape).  Returns (mean-reduced gradient, new residual).
    """
    n = jax.lax.psum(1, axis_name)
    target = x.astype(jnp.float32) + error.astype(jnp.float32)
    q, scale = quantize_int8(target)
    recon_local = q.astype(jnp.float32) * scale
    new_error = (target - recon_local).astype(error.dtype)
    # accumulate in int32 (exact for <= 2^23 summands), share scales
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    # every shard quantized with its own scale — psum the per-shard
    # reconstructions is equivalent to psum(q*scale); using the max scale
    # for all shards would halve traffic but bias small shards, so each
    # shard contributes its own scaled payload via a second tiny psum.
    scale_sum = jax.lax.psum(scale, axis_name)
    mean_scale = scale_sum / n
    # NOTE: exactness requires a common scale; we psum(q)*mean_scale which
    # is exact when shards share scale and a <=(max/min scale - 1) relative
    # error otherwise — acceptable with error feedback absorbing the bias.
    out = acc.astype(jnp.float32) * mean_scale / n
    return out.astype(x.dtype), new_error


def compressed_psum_exact(x: jnp.ndarray, axis_name: str,
                          error: jnp.ndarray):
    """Variant with a globally agreed scale (two-phase): exact dequantize at
    the cost of one extra scalar all-reduce before the payload."""
    n = jax.lax.psum(1, axis_name)
    target = x.astype(jnp.float32) + error.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(target)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    new_error = (target - q.astype(jnp.float32) * scale).astype(error.dtype)
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    out = acc.astype(jnp.float32) * scale / n
    return out.astype(x.dtype), new_error
