"""Mamba selective SSM block (arXiv:2312.00752) for the Jamba hybrid.

Chunked associative scan: within a chunk the diagonal recurrence
    s_t = exp(dt_t A) s_{t-1} + dt_t B_t x_t
is evaluated step-serially inside register-resident chunks;
chunk boundaries carry the state with a cumulative-decay correction.  The
conv1d frontend is a causal depthwise convolution with a (d_conv-1)-token
carry for decode.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _causal_conv(x, w, b, carry):
    """x: [B,S,di]; w: [K,di] depthwise; carry: [B,K-1,di] (previous tokens).
    Returns (y [B,S,di], new_carry)."""
    k = w.shape[0]
    xp = jnp.concatenate([carry.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(k))
    new_carry = xp[:, -(k - 1):, :] if k > 1 else carry
    return y + b[None, None, :], new_carry


def mamba_mix(p: dict, x: jnp.ndarray, cfg, state: Tuple, chunk: int = 32,
              scan_impl: str = "unroll"):
    """x: [B,S,d].  state: (ssm [B,di,ds] f32, conv [B,K-1,di]).
    Returns (out [B,S,d], new_state)."""
    m = cfg.mamba
    b, s, d = x.shape
    di = m.d_inner(d)
    ds = m.d_state
    xz = x @ p["in_proj"]
    xr, z = jnp.split(xz, 2, axis=-1)
    s0, conv0 = state
    xc, conv1 = _causal_conv(xr, p["conv_w"], p["conv_b"], conv0)
    xc = jax.nn.silu(xc)

    dbc = xc @ p["x_dbc"]
    dt_rank = p["dt_proj"].shape[0]
    dt_raw, Bm, Cm = jnp.split(dbc, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(dt_raw @ p["dt_proj"]
                         + p["dt_bias"][None, None, :])     # [B,S,di]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # [di,ds]
    dtA = dt.astype(jnp.float32)[..., None] * A[None, None]  # [B,S,di,ds] <=0
    bx = (dt.astype(jnp.float32) * xc.astype(jnp.float32))[..., None] \
        * Bm.astype(jnp.float32)[:, :, None, :]             # [B,S,di,ds]

    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        dtA = jnp.pad(dtA, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
    c = min(chunk, n_chunks * chunk)

    def per_chunk(carry, xs):
        s_prev = carry                                      # [B,di,ds]
        dta_c, bx_c = xs                                    # [B,c,di,ds]
        if scan_impl == "unroll":
            # UNROLLED in-chunk recurrence: the chunk fuses into elementwise
            # kernels with the running state in registers; HBM traffic is
            # read(dtA,bx) + write(s_all) — the intrinsic minimum.  The
            # associative_scan variant pays 2·log2(chunk) full-array passes
            # (kept selectable for the §Perf A/B; see EXPERIMENTS.md).
            states = []
            cur = s_prev
            for i in range(c):
                cur = jnp.exp(dta_c[:, i]) * cur + bx_c[:, i]
                states.append(cur)
            s_c = jnp.stack(states, axis=1)
            return cur, s_c

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 + a2, jnp.exp(a2) * b1 + b2

        loga, s_local = jax.lax.associative_scan(
            combine, (dta_c, bx_c), axis=1)
        s_c = s_local + jnp.exp(loga) * s_prev[:, None]
        return s_c[:, -1], s_c

    xs = tuple(a.reshape(b, n_chunks, c, di, ds).transpose(1, 0, 2, 3, 4)
               for a in (dtA, bx))
    s_fin, s_all = jax.lax.scan(per_chunk, s0.astype(jnp.float32), xs)
    s_all = s_all.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * c, di, ds)
    s_all = s_all[:, :s]
    y = jnp.einsum("bsdn,bsn->bsd", s_all, Cm.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, None] * xc.astype(jnp.float32)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out, (s_fin.astype(s0.dtype), conv1)
