"""Tiny interval-set utility for the OTCD pruning schedule.

The OTCD schedule over a window with n distinct timestamps has n(n+1)/2
cells; materializing it is quadratic.  Instead each row keeps a merged list
of pruned column-index intervals — O(#prune triggers) memory, exactly the
cells the paper's Figure 4b shades.
"""

from __future__ import annotations

import bisect
from typing import Iterable, List, Tuple


class IntervalSet:
    """Disjoint, sorted, inclusive integer intervals with point queries."""

    def __init__(self, intervals: Iterable[Tuple[int, int]] = ()):  # noqa: D107
        ivs = sorted((int(a), int(b)) for a, b in intervals if a <= b)
        merged: List[Tuple[int, int]] = []
        for a, b in ivs:
            if merged and a <= merged[-1][1] + 1:
                pa, pb = merged[-1]
                merged[-1] = (pa, max(pb, b))
            else:
                merged.append((a, b))
        self._ivs = merged
        self._los = [a for a, _ in merged]

    def add(self, lo: int, hi: int) -> int:
        """Insert [lo, hi]; returns the number of NEWLY covered integers
        (exact per-rule pruning accounting, paper Table 4)."""
        if lo > hi:
            return 0
        new = (hi - lo + 1) - self.total_covered(lo, hi)
        if new == 0 and self.covers(lo) and self.covers(hi):
            return 0
        i = bisect.bisect_left(self._los, lo)
        # merge with neighbours
        start = i
        if start > 0 and self._ivs[start - 1][1] >= lo - 1:
            start -= 1
        end = start
        a, b = lo, hi
        while end < len(self._ivs) and self._ivs[end][0] <= hi + 1:
            a = min(a, self._ivs[end][0])
            b = max(b, self._ivs[end][1])
            end += 1
        self._ivs[start:end] = [(a, b)]
        self._los = [x for x, _ in self._ivs]
        return new

    def covers(self, x: int) -> bool:
        i = bisect.bisect_right(self._los, x) - 1
        return i >= 0 and self._ivs[i][0] <= x <= self._ivs[i][1]

    def highest_uncovered_leq(self, x: int):
        """Largest y <= x not covered by any interval, or None."""
        while True:
            i = bisect.bisect_right(self._los, x) - 1
            if i < 0 or x > self._ivs[i][1]:
                return x
            x = self._ivs[i][0] - 1
            if x < 0:
                return None

    def total_covered(self, lo: int, hi: int) -> int:
        """Number of covered integers within [lo, hi]."""
        n = 0
        for a, b in self._ivs:
            a2, b2 = max(a, lo), min(b, hi)
            if a2 <= b2:
                n += b2 - a2 + 1
        return n

    def __repr__(self) -> str:
        return f"IntervalSet({self._ivs})"
