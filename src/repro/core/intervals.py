"""Tiny interval-set utility for the OTCD pruning schedule.

The OTCD schedule over a window with n distinct timestamps has n(n+1)/2
cells; materializing it is quadratic.  Instead each row keeps a merged list
of pruned column-index intervals — O(#prune triggers) memory, exactly the
cells the paper's Figure 4b shades.
"""

from __future__ import annotations

import bisect
from typing import Iterable, List, Tuple


class IntervalSet:
    """Disjoint, sorted, inclusive integer intervals with point queries."""

    def __init__(self, intervals: Iterable[Tuple[int, int]] = ()):  # noqa: D107
        ivs = sorted((int(a), int(b)) for a, b in intervals if a <= b)
        merged: List[Tuple[int, int]] = []
        for a, b in ivs:
            if merged and a <= merged[-1][1] + 1:
                pa, pb = merged[-1]
                merged[-1] = (pa, max(pb, b))
            else:
                merged.append((a, b))
        self._ivs = merged
        self._los = [a for a, _ in merged]

    def add(self, lo: int, hi: int) -> int:
        """Insert [lo, hi]; returns the number of NEWLY covered integers
        (exact per-rule pruning accounting, paper Table 4)."""
        if lo > hi:
            return 0
        ivs = self._ivs
        los = self._los
        # merge with neighbours; count already-covered integers in the
        # same bounded sweep (intervals are disjoint with gaps >= 2, so a
        # fully covered [lo, hi] lies inside one existing interval)
        start = bisect.bisect_left(los, lo)
        if start > 0 and ivs[start - 1][1] >= lo - 1:
            start -= 1
        end = start
        a, b = lo, hi
        covered = 0
        while end < len(ivs) and ivs[end][0] <= hi + 1:
            ia, ib = ivs[end]
            a2, b2 = max(ia, lo), min(ib, hi)
            if a2 <= b2:
                covered += b2 - a2 + 1
            if ia < a:
                a = ia
            if ib > b:
                b = ib
            end += 1
        new = (hi - lo + 1) - covered
        if new == 0:
            return 0
        ivs[start:end] = [(a, b)]
        los[start:end] = [a]
        return new

    def covers(self, x: int) -> bool:
        i = bisect.bisect_right(self._los, x) - 1
        return i >= 0 and self._ivs[i][0] <= x <= self._ivs[i][1]

    def highest_uncovered_leq(self, x: int):
        """Largest y <= x not covered by any interval, or None."""
        while True:
            i = bisect.bisect_right(self._los, x) - 1
            if i < 0 or x > self._ivs[i][1]:
                return x
            x = self._ivs[i][0] - 1
            if x < 0:
                return None

    def total_covered(self, lo: int, hi: int) -> int:
        """Number of covered integers within [lo, hi]."""
        if lo > hi:
            return 0
        i = bisect.bisect_left(self._los, lo)
        if i > 0 and self._ivs[i - 1][1] >= lo:
            i -= 1
        n = 0
        while i < len(self._ivs) and self._ivs[i][0] <= hi:
            a, b = self._ivs[i]
            n += min(b, hi) - max(a, lo) + 1
            i += 1
        return n

    def __repr__(self) -> str:
        return f"IntervalSet({self._ivs})"
