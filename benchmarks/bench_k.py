"""Paper Figs. 9/10/11: impact of k on response time, number of distinct
cores, and connected components inside the result cores."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, engine, graph, pick_queries, timeit


def _n_components(g, core) -> int:
    """Union-find over the core's edges (host-side)."""
    verts = core.vertices
    if verts.size == 0:
        return 0
    idx = {int(v): i for i, v in enumerate(verts)}
    parent = list(range(len(verts)))

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    lo, hi = core.tti
    m = (g.t >= lo) & (g.t <= hi)
    vset = set(idx)
    for u, v in zip(g.src[m], g.dst[m]):
        u, v = int(u), int(v)
        if u in vset and v in vset:
            ra, rb = find(idx[u]), find(idx[v])
            if ra != rb:
                parent[ra] = rb
    return len({find(i) for i in range(len(verts))})


def run(name: str = "collegemsg", span_uts: int = 90):
    g = graph(name)
    eng = engine(name)
    q = pick_queries(name, 1, span_uts=span_uts, seed=9)[0]
    rows = []
    for k in range(2, 7):
        t_otcd = timeit(lambda: eng.query(k, q["ts"], q["te"]), repeat=2)
        t_tcd = timeit(lambda: eng.query(k, q["ts"], q["te"],
                                         algorithm="tcd"))
        res = eng.query(k, q["ts"], q["te"])
        n_cc = int(np.sum([_n_components(g, c) for c in res.cores]))
        sizes = [c.n_vertices for c in res.cores]
        rows.append({
            "graph": name, "k": k, "ts": q["ts"], "te": q["te"],
            "t_otcd_s": t_otcd, "t_tcd_s": t_tcd,
            "n_cores": len(res), "n_components": n_cc,
            "avg_core_size": float(np.mean(sizes)) if sizes else 0.0,
        })
    emit("bench_k", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
