"""TCD / OTCD query scheduling (paper §3–§4) over the device engines.

The schedule bookkeeping (which (ts, te) cells remain, per the three pruning
rules) is inherently sequential, tiny, and lives on host — it is factored
into ``core/scheduler.py`` (:class:`~repro.core.scheduler.QueryState`:
row cursors, IntervalSet pruning, empty-cell staircase, warm starts, TTI
dedup).  Every TCD operation (truncate + peel + TTI) is a single compiled
device program with dynamic window/threshold scalars — one compilation
serves the whole query.  All modes peel against a *windowed* TEL
(:meth:`TCQEngine._window_tel`, an LRU-cached power-of-two-bucketed
truncation) so per-cell peel work scales with the query window, not |E|.

Enumeration is over *unique* timestamps inside [Ts, Te] (column index space);
cells between adjacent real timestamps are exact duplicates of their
right-snap and are never scheduled (a strict, exact strengthening of PoR).

Three execution modes share that schedule:

* ``serial`` — paper-faithful: one cell per device program (`tcd.tcd`),
  decremental warm starts along each row (Theorem 1).
* ``wave`` — the device-resident lane pool (`engine.WavePipeline`): a
  persistent donated [W, V] lane buffer, one fused ``wave_step`` (peel +
  TTI + stats + uint32 bitmask pack) per batch of schedule cells with
  per-lane (ts, te, k, h), packed O(W·V/32) result transfer with deferred
  bulk decode, and a depth-D slot ring so host pruning bookkeeping
  overlaps device compute.  The Pallas ``banded_segsum`` degree closures
  are built once per engine.
* ``wave_stepwise`` — the seed batched engine, retained as the benchmark
  baseline for the pipeline (one host round-trip per step, per-core [V]
  bool transfers, re-stacked lane batches).

:meth:`TCQEngine.query_batch` serves *many* queries through one shared
lane pool: cells from concurrent queries with heterogeneous (k, h,
window) pack into the same fused steps (per-lane thresholds), keeping
the device full while each query retires independently with results
bit-identical to running it alone.
"""

from __future__ import annotations

import time
from collections import OrderedDict, defaultdict, deque
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core import tcd as tcd_mod
from repro.core.engine import WavePipeline
from repro.core.graph import DeviceTEL, TemporalGraph
from repro.core.intervals import IntervalSet
from repro.core.results import CoreResult, QueryStats, TCQResult
from repro.core.scheduler import EmptyStaircase, QueryState, autotune_wave
from repro.core.wave import make_segsum_fns

_I32_MAX = np.iinfo(np.int32).max
_WINDOW_CACHE_MAX = 64


class TCQEngine:
    """Holds the device TEL + compiled TCD programs for one temporal graph.

    ``use_kernel`` selects the batched degree path for wave mode: True
    forces the Pallas banded kernel (interpret mode off-TPU), False the
    XLA segment-sum reference, None (default) auto-dispatches.  The
    closures — including the kernel's k_max band analysis — are built
    once here and reused by every wave query on this engine.
    """

    def __init__(self, graph: TemporalGraph, degree_fn=None, *,
                 use_kernel: Optional[bool] = None):
        from repro.kernels.segdeg.ops import on_tpu

        self.graph = graph
        self.tel = graph.device_tel()
        self.num_vertices = graph.num_vertices
        self._degree_fn = degree_fn
        self._ones = jnp.ones((graph.num_vertices,), dtype=bool)
        self._use_kernel = on_tpu() if use_kernel is None else use_kernel
        self._seg_pair, self._seg_vert = make_segsum_fns(
            graph, use_kernel=self._use_kernel)
        self._win_cache: "OrderedDict[Tuple[int, int], tuple]" = OrderedDict()

    # -------------------------------------------------------- window slicing
    def _window_tel(self, Ts: int, Te: int):
        """(tel, seg_pair, window_edges): device TEL truncated to [Ts, Te].

        Every cell of a query's schedule lies inside [Ts, Te], so both the
        serial engine and the wave pipeline peel against only the window's
        edges — per-iteration work scales with the window, not the whole
        graph.  Edge arrays are padded to a power-of-two bucket with
        sentinel edges (t=int32 min, pair_id=P, ignored by both degree
        paths), so compiled programs are shared across windows of similar
        size; the vertex-side segsum closure is window-independent and
        always reused.  On the XLA degree path the pair-side closure is
        reused too (it only fixes num_segments); the Pallas path rebuilds
        it because its k_max band analysis depends on the windowed segment
        ids.  The cache is LRU (hits move to the back, the front is
        evicted): serving workloads with a hot set of windows keep their
        compiled buckets instead of churning recompiles.
        """
        key = (int(Ts), int(Te))
        hit = self._win_cache.get(key)
        if hit is not None:
            self._win_cache.move_to_end(key)
            return hit
        g = self.graph
        idx = np.flatnonzero((g.t >= Ts) & (g.t <= Te))
        e = int(idx.size)
        if e >= g.num_edges:
            out = (self.tel, self._seg_pair, e)
        else:
            bucket = max(128, 1 << max(0, e - 1).bit_length())
            pad = bucket - e
            p = g.num_pairs
            # sentinel timestamp must be below every representable window
            # (t = -1 would collide with graphs using negative timestamps)
            t_pad = np.iinfo(np.int32).min
            t_w = np.concatenate([g.t[idx], np.full(pad, t_pad, np.int32)])
            pid_w = np.concatenate([g.pair_id[idx], np.full(pad, p, np.int32)])
            tel = DeviceTEL(
                src=jnp.asarray(np.concatenate(
                    [g.src[idx], np.zeros(pad, np.int32)])),
                dst=jnp.asarray(np.concatenate(
                    [g.dst[idx], np.zeros(pad, np.int32)])),
                t=jnp.asarray(t_w),
                pair_id=jnp.asarray(pid_w),
                pair_u=self.tel.pair_u,
                pair_v=self.tel.pair_v,
                hp_src=self.tel.hp_src,
                hp_pair=self.tel.hp_pair,
                time_perm=jnp.asarray(
                    np.argsort(t_w, kind="stable").astype(np.int32)),
            )
            if self._use_kernel:
                from repro.kernels.segdeg.ops import make_banded_segsum

                seg_pair = make_banded_segsum(pid_w, p, use_kernel=True)
            else:
                seg_pair = self._seg_pair
            out = (tel, seg_pair, e)
        if len(self._win_cache) >= _WINDOW_CACHE_MAX:
            self._win_cache.popitem(last=False)     # evict least-recent
        self._win_cache[key] = out
        return out

    # ------------------------------------------------------------- primitives
    def _tcd(self, alive, ts, te, k, h, tel: Optional[DeviceTEL] = None):
        return tcd_mod.tcd(self.tel if tel is None else tel,
                           alive, ts, te, k, h,
                           num_vertices=self.num_vertices,
                           degree_fn=self._degree_fn)

    def _tcd_batch(self, alive, ts, te, k, h):
        return tcd_mod.tcd_batch(self.tel, alive, ts, te, k, h,
                                 num_vertices=self.num_vertices,
                                 degree_fn=self._degree_fn)

    # ------------------------------------------------------------------ query
    def query(self, k: int, Ts: int, Te: int, *, h: int = 1,
              algorithm: str = "otcd", mode: str = "serial",
              wave: Union[int, str] = 8, depth: int = 2,
              min_span: Optional[int] = None,
              max_span: Optional[int] = None) -> TCQResult:
        """All distinct temporal k-cores over subintervals of [Ts, Te].

        algorithm: "otcd" (TTI pruning, §4) or "tcd" (full enumeration, §3).
        mode: "serial" (paper-faithful), "wave" (device-resident lane pool
        — up to ``wave`` schedule cells per fused device step, ``depth``
        steps in flight), or "wave_stepwise" (the seed batched engine,
        kept as the benchmark baseline).
        wave: lane count for wave mode, or "auto" to pick it from the
        vertex count and the windowed edge count (scheduler.autotune_wave).
        depth: slot-ring depth D for wave mode (pipelining; pruning seen
        by in-flight steps is up to D-1 steps stale, still exact).
        h: link-strength lower bound (paper §6.2); 1 = plain TCQ.
        min_span/max_span: time-span constraint (paper §6.2), applied on the
        fly; pruning stays exact because it is TTI-based.
        """
        t0 = time.perf_counter()
        uts = self.graph.unique_ts
        uts = uts[(uts >= Ts) & (uts <= Te)].astype(np.int64)
        n = int(uts.size)
        stats = QueryStats(n_timestamps=n, cells_total=n * (n + 1) // 2)
        if n == 0:
            return TCQResult([], stats)
        prune = algorithm == "otcd"
        if mode == "wave" and self._degree_fn is not None:
            # custom degree semantics are only plumbed through the
            # scalar/vmapped TCD path; run the stepwise engine (which
            # honors degree_fn) rather than silently ignoring the override
            mode = "wave_stepwise"
        if mode == "wave":
            tel_w, seg_pair_w, e_w = self._window_tel(int(uts[0]),
                                                      int(uts[-1]))
            stats.window_edges = e_w
            if wave == "auto":
                wave = autotune_wave(self.num_vertices, e_w)
            pipe = WavePipeline(tel_w, self.num_vertices,
                                seg_pair_w, self._seg_vert, wave, depth)
            cores = pipe.run(uts, k, h, prune, stats)
        elif mode == "wave_stepwise":
            stats.window_edges = self.graph.num_edges
            cores = self._run_wave_stepwise(uts, k, h, prune,
                                            8 if wave == "auto" else wave,
                                            stats)
        elif self._degree_fn is not None:
            # custom degree fns are written against the graph's real TEL
            # layout — never hand them the bucket-padded window truncation
            stats.window_edges = self.graph.num_edges
            cores = self._run_serial(uts, k, h, prune, stats)
        else:
            # serial peels against the same windowed TEL as wave mode:
            # per-cell work scales with the window's edges, not |E|
            tel_w, _, e_w = self._window_tel(int(uts[0]), int(uts[-1]))
            stats.window_edges = e_w
            cores = self._run_serial(uts, k, h, prune, stats, tel_w)
        out = list(cores.values())
        stats.wall_time_s = time.perf_counter() - t0
        res = TCQResult(out, stats)
        if min_span is not None or max_span is not None:
            res = res.filter_span(min_span, max_span)
        return res

    # ------------------------------------------------------------ query batch
    def query_batch(self, requests: Sequence[Mapping], *,
                    algorithm: str = "otcd", wave: Union[int, str] = "auto",
                    depth: int = 2) -> List[TCQResult]:
        """Serve many concurrent TCQ queries through one shared lane pool.

        ``requests`` is a sequence of mappings with keys ``k``, ``ts``,
        ``te`` and optionally ``h`` (default 1) — the format produced by
        ``repro.data.TCQRequestStream``.  Each request gets its own
        :class:`~repro.core.scheduler.QueryState` (private pruning, warm
        starts, TTI dedup), while the lane pool packs ready cells from
        every in-flight query into shared fused steps with per-lane
        (ts, te, k, h).  One TEL truncated to the *union* window serves
        the whole batch; per-lane windows keep each query's exact
        semantics, so every returned ``TCQResult`` is bit-identical to
        running that query alone.  Throughput improves because lanes
        freed by one query's draining tail are refilled with another's
        cells instead of idling — best when the batch's windows overlap
        (a serving hot set): per-iteration peel cost scales with the
        *union* window's edges, so batching a few narrow windows from
        opposite ends of a long timeline can cost more than looping
        ``query()`` (group such requests into separate batches).

        Per-query ``QueryStats`` carry that query's schedule counters;
        pipeline counters (device_steps, host_syncs, occupancy, ...)
        describe the shared batch and are reported on every member (see
        :class:`~repro.core.results.QueryStats`).

        wave: lane count, or "auto" (default) — autotuned from the vertex
        count, the union window's edge count, and the batch size.
        depth: slot-ring depth D (D steps in flight).
        """
        t0 = time.perf_counter()
        reqs = [dict(r) for r in requests]
        prune = algorithm == "otcd"
        if self._degree_fn is not None:
            # custom degree semantics: fall back to per-query scheduling
            # (the scalar TCD path honors degree_fn; the fused wave step
            # does not)
            return [self.query(int(r["k"]), int(r["ts"]), int(r["te"]),
                               h=int(r.get("h", 1)), algorithm=algorithm)
                    for r in reqs]
        outs: List[Optional[TCQResult]] = [None] * len(reqs)
        states: List[Tuple[int, QueryState]] = []
        for qi, r in enumerate(reqs):
            uts = self.graph.unique_ts
            uts = uts[(uts >= int(r["ts"])) & (uts <= int(r["te"]))]
            uts = uts.astype(np.int64)
            n = int(uts.size)
            stats = QueryStats(n_timestamps=n,
                               cells_total=n * (n + 1) // 2,
                               batch_size=len(reqs))
            if n == 0:
                outs[qi] = TCQResult([], stats)
                continue
            states.append((qi, QueryState(uts, int(r["k"]),
                                          int(r.get("h", 1)), prune,
                                          stats, qid=qi)))
        if states:
            lo = min(int(s.uts[0]) for _, s in states)
            hi = max(int(s.uts[-1]) for _, s in states)
            tel_w, seg_pair_w, e_w = self._window_tel(lo, hi)
            if wave == "auto":
                wave = autotune_wave(self.num_vertices, e_w,
                                     num_queries=len(states))
            pool_stats = QueryStats()
            pipe = WavePipeline(tel_w, self.num_vertices, seg_pair_w,
                                self._seg_vert, wave, depth)
            pipe.run_pool([s for _, s in states], pool_stats)
            for qi, s in states:
                st = s.stats
                st.window_edges = e_w
                st.device_steps = pool_stats.device_steps
                st.host_syncs = pool_stats.host_syncs
                st.bytes_synced = pool_stats.bytes_synced
                st.peel_iters = pool_stats.peel_iters
                st.lane_refills = pool_stats.lane_refills
                st.occupancy = pool_stats.occupancy
                cores = s.decode_results(self.num_vertices)
                outs[qi] = TCQResult(list(cores.values()), st)
        wall = time.perf_counter() - t0
        for out in outs:
            out.stats.wall_time_s = wall
        return outs

    # ----------------------------------------------------------- serial mode
    def _run_serial(self, uts, k, h, prune, stats,
                    tel: Optional[DeviceTEL] = None):
        n = uts.size
        idx_of = {int(t): i for i, t in enumerate(uts)}
        pruned: Dict[int, IntervalSet] = defaultdict(IntervalSet)
        results: Dict[Tuple[int, int], CoreResult] = {}
        empty_col_max = -1          # cells (r, c<=bound) are provably empty
        row_alive = None            # warm start across rows (Theorem 1)
        row_alive_j = -1
        for i in range(n):
            iv = pruned.pop(i, IntervalSet())
            j: Optional[int] = n - 1
            cur_alive = None
            first_in_row = True
            while j is not None and j >= i:
                j = iv.highest_uncovered_leq(j)
                if j is None or j < i:
                    break
                if j <= empty_col_max:
                    stats.cells_trivial += (j - i + 1) - iv.total_covered(i, j)
                    break
                if cur_alive is not None:
                    warm = cur_alive
                elif row_alive is not None and j <= row_alive_j:
                    warm = row_alive
                else:
                    warm = self._ones
                res = self._tcd(warm, int(uts[i]), int(uts[j]), k, h, tel)
                stats.cells_evaluated += 1
                stats.device_steps += 1
                if int(res.n_edges) == 0:
                    if j > i:
                        stats.pruned_empty += (j - i) - iv.total_covered(i, j - 1)
                    empty_col_max = max(empty_col_max, j)
                    if j == n - 1:
                        # T[ts_i, Te] empty => all deeper rows empty
                        stats.cells_trivial += sum(
                            n - r for r in range(i + 1, n))
                        return results
                    break
                cur_alive = res.alive
                if first_in_row:
                    row_alive, row_alive_j = res.alive, j
                    first_in_row = False
                a_idx = idx_of[int(res.tti_lo)]
                b_idx = idx_of[int(res.tti_hi)]
                self._collect(results, res, a_idx, b_idx, uts, k, stats)
                if prune:
                    if b_idx < j:                       # Rule 1: PoR
                        stats.por_triggers += 1
                        stats.pruned_por += (j - b_idx) - iv.total_covered(
                            b_idx, j - 1)
                    if a_idx > i:                       # Rule 2: PoU
                        stats.pou_triggers += 1
                        for r in range(i + 1, a_idx + 1):
                            stats.pruned_pou += pruned[r].add(r, j)
                    if a_idx > i and b_idx < j:         # Rule 3: PoL
                        stats.pol_triggers += 1
                        for r in range(a_idx + 1, b_idx + 1):
                            stats.pruned_pol += pruned[r].add(b_idx + 1, j)
                    j = (b_idx - 1) if b_idx < j else j - 1
                else:
                    j = j - 1
        return results

    # ------------------------------------------- stepwise wave (seed baseline)
    def _run_wave_stepwise(self, uts, k, h, prune, wave, stats):
        """Seed batched engine: up to ``wave`` cells per device step, with a
        blocking host round-trip between steps and per-core [V] bool
        transfers.  Retained as the measured baseline for the pipelined
        engine (see engine.WavePipeline and benchmarks/bench_pipeline.py).

        Rows advance concurrently; pruning triggered by any lane applies to
        all not-yet-evaluated cells (lanes already in flight may compute a
        duplicate — counted, and removed by TTI dedup per Property 2).
        """
        n = uts.size
        idx_of = {int(t): i for i, t in enumerate(uts)}
        results: Dict[Tuple[int, int], CoreResult] = {}
        pruned: Dict[int, IntervalSet] = defaultdict(IntervalSet)
        # empty cells form a staircase: cell (i_e, j_e) empty => all
        # (r>=i_e, c<=j_e) empty.  Wave mode needs the row condition
        # explicitly (rows are concurrent, unlike the ascending serial
        # sweep); the incremental corner list is shared with the pipeline
        # via scheduler.EmptyStaircase.
        empty = EmptyStaircase()
        best_init = None  # (row, col, alive) of a completed row-initial cell

        class Row:
            __slots__ = ("i", "j", "alive", "first")

            def __init__(self, i):
                self.i, self.j, self.alive, self.first = i, n - 1, None, True

        pending = deque(range(n))
        active: List[Row] = []

        def advance(row: Row) -> bool:
            """Move cursor past pruned/empty cells; False when row exhausted."""
            j = pruned[row.i].highest_uncovered_leq(row.j)
            if j is None or j < row.i or j <= empty.bound(row.i):
                return False
            row.j = j
            return True

        while pending or active:
            while len(active) < wave and pending:
                r = Row(pending.popleft())
                if advance(r):
                    active.append(r)
            if not active:
                break
            # assemble one fixed-width batch (pad with dead lanes)
            lanes = list(active)
            alive_stack, ts_arr, te_arr = [], [], []
            for r in lanes:
                if r.alive is not None:
                    warm = r.alive
                elif (best_init is not None and best_init[0] <= r.i
                      and best_init[1] >= r.j):
                    warm = best_init[2]
                else:
                    warm = self._ones
                alive_stack.append(warm)
                ts_arr.append(int(uts[r.i]))
                te_arr.append(int(uts[r.j]))
            pad = wave - len(lanes)
            for _ in range(pad):
                alive_stack.append(jnp.zeros_like(self._ones))
                ts_arr.append(0)
                te_arr.append(-1)
            res = self._tcd_batch(
                jnp.stack(alive_stack),
                jnp.asarray(ts_arr, dtype=jnp.int32),
                jnp.asarray(te_arr, dtype=jnp.int32), k, h)
            stats.device_steps += 1
            stats.cells_evaluated += len(lanes)
            n_edges = np.asarray(res.n_edges)
            tti_lo = np.asarray(res.tti_lo)
            tti_hi = np.asarray(res.tti_hi)
            stats.host_syncs += 3
            stats.bytes_synced += n_edges.nbytes + tti_lo.nbytes + tti_hi.nbytes
            survivors: List[Row] = []
            for li, row in enumerate(lanes):
                i, j = row.i, row.j
                if int(n_edges[li]) == 0:
                    empty.add(i, j)
                    continue  # row exhausted: all deeper cells empty
                row.alive = res.alive[li]
                a_idx = idx_of[int(tti_lo[li])]
                b_idx = idx_of[int(tti_hi[li])]
                one = tcd_mod.TCDResult(res.alive[li], tti_lo[li], tti_hi[li],
                                        n_edges[li], res.n_verts[li])
                self._collect(results, one, a_idx, b_idx, uts, k, stats)
                if row.first and (best_init is None or j >= best_init[1]):
                    best_init = (i, j, res.alive[li])
                row.first = False
                if prune:
                    if b_idx < j:
                        stats.por_triggers += 1
                        stats.pruned_por += pruned[i].add(b_idx, j - 1)
                    if a_idx > i:
                        stats.pou_triggers += 1
                        for r2 in range(i + 1, a_idx + 1):
                            stats.pruned_pou += pruned[r2].add(r2, j)
                    if a_idx > i and b_idx < j:
                        stats.pol_triggers += 1
                        for r2 in range(a_idx + 1, b_idx + 1):
                            stats.pruned_pol += pruned[r2].add(b_idx + 1, j)
                    row.j = (b_idx - 1) if b_idx < j else j - 1
                else:
                    row.j = j - 1
                if advance(row):
                    survivors.append(row)
            active = survivors
        return results

    # ---------------------------------------------------------------- collect
    def _collect(self, results, res, a_idx, b_idx, uts, k, stats):
        key = (int(uts[a_idx]), int(uts[b_idx]))
        if key in results:
            stats.duplicates += 1
            return
        alive = np.asarray(res.alive)          # full [V] bool transfer
        stats.host_syncs += 1
        stats.bytes_synced += alive.nbytes
        verts = np.flatnonzero(alive)
        results[key] = CoreResult(k=k, tti=key, vertices=verts,
                                  n_edges=int(res.n_edges))


def temporal_kcore_query(graph: TemporalGraph, k: int, Ts: int, Te: int,
                         **kw) -> TCQResult:
    """One-shot convenience wrapper (builds a throwaway engine)."""
    return TCQEngine(graph).query(k, Ts, Te, **kw)
