"""Shared model layers: norms, rotary embeddings (incl. M-RoPE), softcaps."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def norm(x, params, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def _rope_angles(positions: jnp.ndarray, dim: int, theta: float) -> Tuple:
    """positions [..., S] -> cos/sin [..., S, dim/2] in f32."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections: Optional[Tuple[int, ...]] = None) -> jnp.ndarray:
    """Rotary embedding.  x: [B, S, H, hd]; positions: [B, S] or [3, B, S]
    (M-RoPE: temporal/height/width position streams — qwen2-vl §3.1, with the
    modality frontend stubbed the three streams arrive precomputed)."""
    hd = x.shape[-1]
    if positions.ndim == 3:  # M-RoPE
        secs = mrope_sections
        assert secs is not None and sum(secs) == hd // 2
        cos_parts, sin_parts = [], []
        start = 0
        for si, sec in enumerate(secs):
            # each head-dim section rotates by its own position stream
            freqs = 1.0 / (theta ** (
                (jnp.arange(start, start + sec, dtype=jnp.float32) * 2) / hd))
            ang = positions[si].astype(jnp.float32)[..., None] * freqs
            cos_parts.append(jnp.cos(ang))
            sin_parts.append(jnp.sin(ang))
            start += sec
        cos = jnp.concatenate(cos_parts, -1)[:, :, None, :]
        sin = jnp.concatenate(sin_parts, -1)[:, :, None, :]
    else:
        cos, sin = _rope_angles(positions, hd, theta)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def activation(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "relu_sq":  # RWKV channel-mix
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def group_rmsnorm(x: jnp.ndarray, scale: jnp.ndarray,
                  eps: float = 64e-5) -> jnp.ndarray:
    """Per-head group norm (RWKV output norm). x: [B, S, H, hd]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)
