"""Paper Table 4: pruning-rule trigger counts and pruned-cell percentages."""

from __future__ import annotations

from benchmarks.common import GRAPH_K, emit, engine, pick_queries


def run(per_graph: int = 2, span_uts: int = 90):
    rows = []
    for name in ("collegemsg", "email", "mathoverflow"):
        eng = engine(name)
        for q in pick_queries(name, per_graph, span_uts=span_uts, seed=5):
            k = q["k"]
            s = eng.query(k, q["ts"], q["te"]).stats
            denom = max(1, s.cells_total)
            rows.append({
                "graph": name, "k": k, "ts": q["ts"], "te": q["te"],
                "cells_total": s.cells_total,
                "por_triggers": s.por_triggers,
                "pou_triggers": s.pou_triggers,
                "pol_triggers": s.pol_triggers,
                "pct_por": 100.0 * s.pruned_por / denom,
                "pct_pou": 100.0 * s.pruned_pou / denom,
                "pct_pol": 100.0 * s.pruned_pol / denom,
                "pct_empty": 100.0 * s.pruned_empty / denom,
                "pct_total_pruned": s.pruned_pct(),
                "duplicates": s.duplicates,
            })
    emit("bench_pruning", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
