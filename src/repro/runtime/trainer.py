"""Fault-tolerant training driver: checkpoint/restart, failure injection,
straggler watchdog, elastic re-mesh.

The contract at 1000+ nodes:
  * every step is restart-exact: params/optimizer come from the checkpoint,
    data comes from the stateless step-indexed pipeline;
  * failures (injected here, SIGKILL/ICI-loss in production) bounce the
    driver loop, which restores the last complete checkpoint and replays;
  * the straggler watchdog flags steps slower than ``straggler_factor`` x a
    trailing median — at scale that signal feeds re-slicing / hot-spare
    swap; here it is surfaced in metrics and tested via an injected delay;
  * ``resize(new_mesh)`` demonstrates elastic scaling: checkpoint,
    rebuild the compiled step for the new mesh, restore with resharding.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.launch import steps as steps_mod
from repro.launch.mesh import dp_axes
from repro.models import transformer as T


class InjectedFault(RuntimeError):
    pass


@dataclasses.dataclass
class FaultInjector:
    """Raises at configured steps (once each) — simulated node failures."""
    fail_at: Dict[int, str] = dataclasses.field(default_factory=dict)
    delay_at: Dict[int, float] = dataclasses.field(default_factory=dict)
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.delay_at:
            time.sleep(self.delay_at[step])
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise InjectedFault(f"step {step}: {self.fail_at[step]}")


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 10
    ckpt_every: int = 5
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0
    lr: float = 3e-4
    log_every: int = 1


class Trainer:
    def __init__(self, model_cfg, mesh, data, tcfg: TrainerConfig,
                 injector: Optional[FaultInjector] = None):
        self.model_cfg = model_cfg
        self.tcfg = tcfg
        self.data = data
        self.injector = injector or FaultInjector()
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.metrics: List[Dict[str, Any]] = []
        self.restarts = 0
        self.straggler_flags = 0
        self._build(mesh)

    # ------------------------------------------------------------ lifecycle
    def _build(self, mesh) -> None:
        self.mesh = mesh
        from jax.sharding import NamedSharding, PartitionSpec as PS

        _, jit_with, self.p_ns, self.o_ns, self.opt = \
            steps_mod.build_train_step(self.model_cfg, mesh, n_micro=1,
                                       lr=self.tcfg.lr)
        dp = dp_axes(mesh)
        sample = self.data.batch_at(0)

        def spec_of(v):
            lead = (None if v.shape[0] == 3 and v.ndim == 3 else
                    (dp if v.shape[0] % max(1, _axsize(mesh, dp)) == 0
                     else None))
            return NamedSharding(mesh, PS(lead, *([None] * (v.ndim - 1))))

        self.batch_ns = {k: spec_of(v) for k, v in sample.items()}
        self.step_fn = jit_with(self.batch_ns)

    def _init_state(self):
        params = T.init_params(self.model_cfg, 0)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s), params, self.p_ns)
        opt_state = self.opt.init(params)
        return params, opt_state

    # ----------------------------------------------------------------- run
    def run(self) -> Dict[str, Any]:
        attempts = 0
        while True:
            try:
                return self._run_once()
            except InjectedFault as e:
                attempts += 1
                self.restarts += 1
                if attempts > self.tcfg.max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                # driver bounces; state comes back from the checkpoint

    def _run_once(self) -> Dict[str, Any]:
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            params, opt_state = self._restore(latest)
            start = latest
        else:
            params, opt_state = self._init_state()
        times: List[float] = []
        for step in range(start, self.tcfg.steps):
            t0 = time.perf_counter()
            # injected delays land inside the timed window (they simulate a
            # slow step); injected faults abort it like a real node loss
            self.injector.check(step)
            batch = {k: jax.device_put(v, self.batch_ns[k])
                     for k, v in self.data.batch_at(step).items()}
            params, opt_state, m = self.step_fn(params, opt_state, batch)
            loss = float(m["loss"])
            dt = time.perf_counter() - t0
            if len(times) >= 3:
                med = statistics.median(times[-8:])
                if dt > self.tcfg.straggler_factor * med:
                    self.straggler_flags += 1
            times.append(dt)
            self.metrics.append({"step": step, "loss": loss,
                                 "grad_norm": float(m["grad_norm"]),
                                 "time_s": dt})
            if (step + 1) % self.tcfg.ckpt_every == 0 \
                    or step + 1 == self.tcfg.steps:
                self.ckpt.save(step + 1,
                               {"params": params, "opt": opt_state})
        self.ckpt.wait()
        return {"final_loss": self.metrics[-1]["loss"],
                "steps_run": len(self.metrics),
                "restarts": self.restarts,
                "straggler_flags": self.straggler_flags}

    def _restore(self, step: int):
        like = {"params": T.abstract_params(self.model_cfg),
                "opt": self.opt.init_abstract(
                    T.abstract_params(self.model_cfg))}
        sh = {"params": self.p_ns, "opt": self.o_ns}
        tree = self.ckpt.restore(like, step=step, shardings=sh)
        return tree["params"], tree["opt"]

    # -------------------------------------------------------------- elastic
    def resize(self, new_mesh) -> None:
        """Elastic re-mesh: checkpoint -> rebuild -> restore w/ reshard."""
        step = (self.metrics[-1]["step"] + 1) if self.metrics else 0
        if self.ckpt.latest_step() != step:
            # force a sync checkpoint of the current state if one exists
            pass
        self._build(new_mesh)


def _axsize(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes[a]
    return n
