from repro.runtime.trainer import (  # noqa: F401
    FaultInjector,
    InjectedFault,
    Trainer,
    TrainerConfig,
)
