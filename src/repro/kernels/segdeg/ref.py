"""Pure-jnp oracle for the banded segment-sum kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def banded_segsum_ref(values: jnp.ndarray, seg_ids: jnp.ndarray,
                      num_segments: int) -> jnp.ndarray:
    """values: [N, Q]; seg_ids: [N] sorted ascending (entries == num_segments
    are padding and ignored).  Returns [num_segments, Q] with
    out[s, q] = sum_{i: seg_ids[i] == s} values[i, q]."""
    return jax.ops.segment_sum(values, seg_ids, num_segments=num_segments,
                               indices_are_sorted=True)
