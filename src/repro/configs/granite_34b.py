"""IBM Granite 34B code model [arXiv:2405.04324] — GPT-BigCode style:
MQA (kv=1), non-GLU GELU MLP, LayerNorm, learned absolute positions."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24_576, vocab=49_152,
    act="gelu", glu=False, norm="layernorm", pos="learned", qkv_bias=True,
    tie_embeddings=True,
    max_seq=32_768,
    notes="MQA; learned positions sized to 32k for the prefill cell",
)
