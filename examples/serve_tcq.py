"""End-to-end TCQ serving driver: batched time-range k-core queries over a
live (dynamically growing) temporal graph — the paper's system as a service.

  * requests arrive as (k, [Ts, Te]) windows (TCQRequestStream);
  * each batch is served through ``TCQEngine.query_batch``: one shared
    multi-tenant lane pool packs schedule cells from every in-flight
    request into the same fused device steps (per-lane k/h/window), so
    lanes freed by one query's draining tail are refilled by another's —
    the reported occupancy is the mean cells per device step;
  * between batches, new edges arrive (EdgeStream) and the ArrayTEL is
    refreshed — the paper's §6.1 dynamic-graph scenario;
  * responses report distinct cores + their TTIs; throughput stats printed.

query_batch in one line::

    results = eng.query_batch([{"k": 4, "ts": 10, "te": 500},
                               {"k": 2, "ts": 40, "te": 90, "h": 2}])

returns one ``TCQResult`` per request, bit-identical to running each
request alone, with the lane count autotuned from the union window.

Run:  PYTHONPATH=src python examples/serve_tcq.py [--requests 12]
"""

import argparse
import time

import numpy as np

from repro.core import TCQEngine
from repro.data import TCQRequestStream
from repro.graphs import EdgeStream, powerlaw_temporal


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--k", type=int, default=4)
    args = ap.parse_args()

    base = powerlaw_temporal(1200, 12_000, 16_384, seed=1)
    future = powerlaw_temporal(1200, 3_000, 4_096, seed=2)

    stream = EdgeStream(base)
    arrivals = EdgeStream.replay(future, 3)
    lo, hi = base.span
    reqs = list(TCQRequestStream(lo, hi, k=args.k, span=400,
                                 seed=0).requests(args.requests))

    eng = TCQEngine(stream.graph)
    lat = []
    for i in range(0, len(reqs), args.batch):
        batch = reqs[i:i + args.batch]
        t0 = time.perf_counter()
        # one shared lane pool serves the whole batch (mixed k/h/windows)
        results = eng.query_batch(batch)
        dt = time.perf_counter() - t0
        lat.append(dt / len(batch))
        for r, res in zip(batch, results):
            print(f"req#{r['id']:03d} k={r['k']} window=[{r['ts']},{r['te']}]"
                  f" -> {len(res)} cores "
                  f"{[c.tti for c in res.top_n_shortest_span(3)]}")
        # pool counters are batch-wide, but empty-window requests never
        # enter the pool — report from a member that did device work
        s = next((r.stats for r in results if r.stats.device_steps), None)
        if s is not None:
            print(f"  [pool] {s.device_steps} steps, "
                  f"occupancy {s.occupancy:.1f} cells/step")
        # dynamic arrival between batches (paper §6.1): incremental
        # merge-append + in-place engine epoch swap — no rebuild
        try:
            u, v, t = next(arrivals)
            t = t + hi  # future timestamps
            g2 = stream.push(u, v, t)
            eng.update_graph(g2)
            print(f"  [stream] +{len(u)} edges -> |E|={g2.num_edges} "
                  f"(epoch {eng.epoch})")
        except StopIteration:
            pass
    print(f"\nserved {len(reqs)} requests; "
          f"mean latency {1e3 * np.mean(lat):.1f} ms/req, "
          f"p95 {1e3 * np.quantile(lat, 0.95):.1f} ms/req")


if __name__ == "__main__":
    main()
