import os

# Tests run against the single real CPU device (no fake-device override here:
# the 512-device mesh belongs exclusively to launch/dryrun.py, which sets
# XLA_FLAGS before jax initializes).  Distributed semantics are unit-tested on
# 1-device meshes; multi-device behaviour is exercised via subprocess tests
# that launch dryrun.py.
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "kernel_gate: interpret-mode fused wave-peel kernel equivalence "
        "gate (CI runs `-m kernel_gate` with REPRO_KERNEL_GATE=1 for the "
        "widened sweep; the tests also run in plain tier-1)")
    config.addinivalue_line(
        "markers",
        "cache_gate: TTI core-cache equivalence gate (CI runs "
        "`-m cache_gate` with REPRO_CACHE_GATE=1 for the widened fuzz "
        "seeds; the tests also run in plain tier-1)")
    config.addinivalue_line(
        "markers",
        "dist_gate: sharded-pipeline equivalence gate (CI runs "
        "`-m dist_gate` with REPRO_DIST_GATE=1 for the widened "
        "multi-mesh sweep; the tests also run in plain tier-1)")
    config.addinivalue_line(
        "markers",
        "wal_gate: write-ahead-journal durability gate — kill-anywhere "
        "crash recovery must be bit-identical (CI runs `-m wal_gate` "
        "with REPRO_WAL_GATE=1 for the every-record kill sweep; the "
        "tests also run, sampled, in plain tier-1)")
