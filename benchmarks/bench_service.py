"""Multi-tenant serving throughput: ``query_batch`` vs sequential loops.

The north star is heavy concurrent query traffic, so the metric here is
batched *throughput* (queries/sec), not single-query latency: a batch of
mixed-(k, h, window) requests served through one shared lane pool is
measured against the same requests answered one at a time — both with the
paper-faithful serial engine (what ``query()`` runs by default) and with
the single-query wave pipeline.  The pool wins by keeping the fused step
full: lanes freed by one query's draining schedule tail are refilled with
another query's cells (mean cells-per-step occupancy is reported).

The batch's results are checked bit-identical (TTI keys, vertex sets,
edge counts) to the per-query serial runs and the run *raises* on any
divergence — run.py turns that into a non-zero exit, so this bench
doubles as a cross-engine regression gate.  Rows feed
benchmarks/results/bench_service.json and the BENCH_wave.json ``service``
trajectory.
"""

from __future__ import annotations

from benchmarks.common import (GRAPH_K, assert_cores_equal, emit, engine,
                               graph, timeit)

N_QUERIES = 8       # concurrent mixed-(k, h) requests in the batch
SPAN_UTS = 48       # unique timestamps per request window
START_UTS = 100     # first window start (index into unique_ts)
STRIDE_UTS = 9      # shift between consecutive request windows


def mixed_requests(name: str, n: int = N_QUERIES):
    """n overlapping windows with heterogeneous (k, h) thresholds."""
    uts = graph(name).unique_ts
    k0 = GRAPH_K[name]
    reqs = []
    for i in range(n):
        i0 = min(START_UTS + STRIDE_UTS * i, max(0, uts.size - SPAN_UTS - 1))
        j0 = min(i0 + SPAN_UTS, uts.size - 1)
        reqs.append({"k": k0 + (i % 3), "h": (1, 1, 2)[i % 3],
                     "ts": int(uts[i0]), "te": int(uts[j0])})
    return reqs


def _check_identical(name, reqs, batch_results, serial_results):
    for r, got, want in zip(reqs, batch_results, serial_results):
        assert_cores_equal(got, want, ctx=f"service on {name} {r}")


def run(name: str = "collegemsg", repeat: int = 2):
    eng = engine(name)
    reqs = mixed_requests(name)

    serial_loop = lambda: [eng.query(r["k"], r["ts"], r["te"], h=r["h"])  # noqa: E731
                           for r in reqs]
    wave_loop = lambda: [eng.query(r["k"], r["ts"], r["te"], h=r["h"],  # noqa: E731
                                   mode="wave", wave=8) for r in reqs]
    batch = lambda: eng.query_batch(reqs)  # noqa: E731

    # warm every compile cache (and grab results for the equivalence gate)
    serial_res = serial_loop()
    wave_res = wave_loop()
    batch_res = batch()
    _check_identical(name, reqs, batch_res, serial_res)
    _check_identical(name, reqs, wave_res, serial_res)

    rows = []
    times = {}
    for mode, fn in (("serial_loop", serial_loop), ("wave_loop", wave_loop),
                     ("batch", batch)):
        t = timeit(fn, repeat=repeat)
        times[mode] = t
        rows.append({"bench": "service", "graph": name, "mode": mode,
                     "n_queries": len(reqs), "t_s": t,
                     "qps": len(reqs) / t})
    bs = batch_res[0].stats
    rows[-1].update({
        "device_steps": bs.device_steps, "host_syncs": bs.host_syncs,
        "occupancy": bs.occupancy, "lane_refills": bs.lane_refills,
        "window_edges": bs.window_edges,
        "cells": sum(r.stats.cells_evaluated for r in batch_res),
    })
    rows.append({
        "bench": "service_summary", "graph": name, "n_queries": len(reqs),
        "speedup_batch_vs_serial_loop": times["serial_loop"] / times["batch"],
        "speedup_batch_vs_wave_loop": times["wave_loop"] / times["batch"],
        "occupancy": bs.occupancy,
        "equivalent": True,     # _check_identical raised otherwise
    })
    emit("bench_service", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
