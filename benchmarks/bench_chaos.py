"""Chaos harness: seeded fault injection over the full serving stack,
gated on bit-identical results vs the fault-free run.

Every scenario replays the *same deterministic workload* (the
anti-union request set of ``bench_streaming``) through a ``TCQService``
whose engine runs the graceful-degradation ladder
(``ResilienceConfig``), with one fault class injected per scenario via
``core/faultinject.py``:

1. ``slow_lane`` — straggler steps (injected sleeps); results must not
   move, only latency.
2. ``kernel_vmem`` — the fused Pallas rung is built under a 1-byte VMEM
   budget (``interpret=False``) and is unavailable from the start: the
   ladder opens on the XLA rung and logs the demotion.
3. ``kernel_failure`` — the XLA rung raises an injected
   :class:`KernelFault` mid-pool; the ladder demotes to the oracle and
   replays the failed call bit-identically.
4. ``divergence`` — the XLA rung silently corrupts one vertex's alive
   bit; the sampled oracle tripwire catches it, quarantines the rung for
   the epoch, and replays on the oracle.
5. ``malformed_ingest`` — a stream of invalid edge batches (negative /
   overflowing / NaN / mismatched / sentinel-colliding) lands mid-run;
   each must raise :class:`GraphIngestError` and leave the graph (and
   every result) untouched.
6. ``midpool_cancel`` — one ticket is cancelled mid-pool and another
   expires via a past deadline; their lanes are reclaimed, both resolve
   with terminal statuses, and every *surviving* ticket stays
   bit-identical.
7. ``crash_restore`` — the service is snapshotted mid-queue, serialized
   through an in-memory ``.npz``, restored, and drained; the union of
   pre-crash and post-restore results must equal the uninterrupted run.

8. ``sharded_rung_fault`` — on an 8-virtual-device lane-sharded mesh
   with the fused kernel rung live, one window pool's Pallas rung takes
   an injected :class:`KernelFault` on its first call: *that* pool's
   :class:`~repro.core.distributed.ShardedDegradationLadder` demotes to
   the sharded XLA rung and replays, every other pool's ladder stays on
   the kernel, and the whole drain is bit-identical to the fault-free
   sharded run (subprocess, like ``bench_distributed`` — jax locks the
   device count at first init).

The **kill-anywhere durability drill** (:func:`run_durability`) extends
the crash scenario to the write-ahead journal (``core/wal.py``): a
deterministic op tape (admissions, ingest batches, a cancellation, a
checkpoint) is applied one entry per poll tick while pools drain, then
the drill kills the service *after every single journal record* (plus:
mid-checkpoint between segment rotation and snapshot write, a torn
tail, a flipped tail byte, a corrupted newest snapshot) and requires
``TCQService.recover`` + drain to be bit-identical to the uninterrupted
run over the surviving journal prefix — graph fingerprint included.
Recovery wall-clock vs journal-tail length forms the
``BENCH_wave.json["durability"]`` curve.

Any divergence raises (``assert_cores_equal``), so ``python -m
benchmarks.run`` — and the CI ``chaos_gate`` / ``wal_gate`` jobs
(``REPRO_CHAOS=1`` / ``REPRO_WAL_GATE=1`` widen the sweeps) — fail on a
broken recovery path exactly like a wrong core.  A final closed-loop
run at ~2x overload records the shed rate and p99 under backpressure
for the BENCH_wave.json ``chaos`` trajectory.
"""

from __future__ import annotations

import io
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

import numpy as np

from benchmarks.bench_streaming import disjoint_requests
from benchmarks.common import SMOKE, assert_cores_equal, emit, graph

CHAOS = os.environ.get("REPRO_CHAOS", "") not in ("", "0")
SEEDS = (0, 1, 2) if CHAOS else (0,)


def _sig(reqs):
    return [(r["k"], r.get("h", 1), r["ts"], r["te"]) for r in reqs]


def _serve(svc, reqs, poll=None):
    tickets = [svc.submit(dict(r)) for r in reqs]
    svc.run_until_idle(poll)
    return tickets


def _gate(tickets, ref, *, skip=(), ctx=""):
    """Every non-skipped ticket bit-identical to the fault-free run."""
    for i, (tk, want) in enumerate(zip(tickets, ref)):
        if i in skip:
            continue
        assert_cores_equal(tk.result, want.result,
                           ctx=f"chaos[{ctx}] req#{i}")


def _events(svc):
    return svc.engine.resilience_events()


def run_scenarios(name: str, seed: int):
    from repro.core import ResilienceConfig, TCQService
    from repro.core.faultinject import (FaultPlan, KernelFault,
                                        malformed_batches, rung_faults)
    from repro.core.graph import GraphIngestError

    g = graph(name)
    reqs = disjoint_requests(name)
    rows = []

    def scenario(tag, fn):
        t0 = time.perf_counter()
        extra = fn()
        rows.append({"bench": "chaos", "scenario": tag, "graph": name,
                     "seed": seed, "n_queries": len(reqs),
                     "equivalent": True,      # the gates above raised
                     "wall_s": time.perf_counter() - t0, **(extra or {})})

    # fault-free reference (ladder on, no injection — the ladder itself
    # must be invisible when nothing fails)
    svc0 = TCQService(g, use_kernel=False,
                      resilience=ResilienceConfig(seed=seed))
    ref = _serve(svc0, reqs)
    assert not _events(svc0), _events(svc0)

    def slow_lane():
        cfg = ResilienceConfig(seed=seed, rung_wrapper=rung_faults(
            {"xla": FaultPlan(slow_at=(0, 2, 5), delay_s=0.02)}))
        svc = TCQService(g, use_kernel=False, resilience=cfg)
        _gate(_serve(svc, reqs), ref, ctx="slow_lane")
        assert not _events(svc), _events(svc)   # stragglers never demote
        return {"demotions": 0}
    scenario("slow_lane", slow_lane)

    def kernel_vmem():
        # fused rung built under an impossible VMEM budget (and
        # interpret=False so the budget check actually runs off-TPU):
        # unavailable from call zero, ladder opens on XLA
        cfg = ResilienceConfig(seed=seed, interpret=False,
                               vmem_budget_bytes=1)
        svc = TCQService(g, use_kernel=True, resilience=cfg)
        _gate(_serve(svc, reqs), ref, ctx="kernel_vmem")
        evs = _events(svc)
        assert evs and all(e["reason"] == "vmem_budget" for e in evs), evs
        return {"demotions": len(evs), "reason": "vmem_budget"}
    scenario("kernel_vmem", kernel_vmem)

    def kernel_failure():
        cfg = ResilienceConfig(seed=seed, rung_wrapper=rung_faults(
            {"xla": FaultPlan(fail_at=(1,))}))
        svc = TCQService(g, use_kernel=False, resilience=cfg)
        _gate(_serve(svc, reqs), ref, ctx="kernel_failure")
        evs = _events(svc)
        assert any(e["reason"] == "error" for e in evs), evs
        return {"demotions": len(evs), "reason": "error"}
    scenario("kernel_failure", kernel_failure)

    def divergence():
        cfg = ResilienceConfig(seed=seed, tripwire_every=1,
                               rung_wrapper=rung_faults(
                                   {"xla": FaultPlan(corrupt_at=(0,))}))
        svc = TCQService(g, use_kernel=False, resilience=cfg)
        _gate(_serve(svc, reqs), ref, ctx="divergence")
        evs = _events(svc)
        assert any(e["reason"] == "divergence" for e in evs), evs
        return {"demotions": len(evs), "reason": "divergence"}
    scenario("divergence", divergence)

    def malformed_ingest():
        svc = TCQService(g, use_kernel=False,
                         resilience=ResilienceConfig(seed=seed))
        bad = malformed_batches(seed)
        state = {"i": 0, "rejected": 0}

        def poll(s):
            if state["i"] < len(bad):
                u, v, t = bad[state["i"]]
                state["i"] += 1
                epoch0 = s.epoch
                try:
                    s.push_edges(u, v, t)
                except GraphIngestError:
                    state["rejected"] += 1
                assert s.epoch == epoch0     # rejected batch: no epoch

        tickets = _serve(svc, reqs, poll)
        # drain any batches the poll never reached (short pools)
        while state["i"] < len(bad):
            poll(svc)
        assert state["rejected"] == len(bad), (state, len(bad))
        _gate(tickets, ref, ctx="malformed_ingest")
        return {"batches_rejected": state["rejected"]}
    scenario("malformed_ingest", malformed_ingest)

    def midpool_cancel():
        svc = TCQService(g, use_kernel=False,
                         resilience=ResilienceConfig(seed=seed))
        tickets = [svc.submit(dict(r)) for r in reqs]
        # one already-expired deadline (times out at the first sweep) ...
        doomed = svc.submit({**reqs[0], "deadline_s": -1.0})
        state = {"polls": 0}

        def poll(s):
            state["polls"] += 1
            if state["polls"] == 2:          # mid-pool: lanes are live
                s.cancel(tickets[0])         # the widest (longest) member
        svc.run_until_idle(poll)
        assert doomed.status == "timeout" and doomed.done
        assert tickets[0].status == "cancelled" and tickets[0].done
        assert tickets[0].result is not None      # partial, not missing
        _gate(tickets, ref, skip={0}, ctx="midpool_cancel")
        return {"cancelled": 1, "timeouts": 1}
    scenario("midpool_cancel", midpool_cancel)

    def crash_restore():
        svc = TCQService(g, use_kernel=False,
                         resilience=ResilienceConfig(seed=seed))
        for r in reqs:
            svc.submit(dict(r))
        early = svc.pump()                   # some resolve pre-crash
        buf = io.BytesIO()
        svc.save_snapshot(buf)               # ... crash ...
        buf.seek(0)
        from repro.core import TCQService as Svc
        svc2 = Svc.load_snapshot(buf, use_kernel=False,
                                 resilience=ResilienceConfig(seed=seed))
        late = svc2.run_until_idle()
        by_id = {tk.id: tk for tk in early + late}
        assert len(by_id) == len(reqs), (sorted(by_id), len(reqs))
        for i in range(len(reqs)):
            assert_cores_equal(by_id[i].result, ref[i].result,
                               ctx=f"chaos[crash_restore] req#{i}")
        return {"resolved_precrash": len(early),
                "resolved_postrestore": len(late)}
    scenario("crash_restore", crash_restore)

    return rows


def run_overload(name: str):
    """Closed loop at ~2x overload: concurrency far above what the
    bounded queue admits, tight deadlines — records shed rate and p99
    under backpressure (the BENCH_wave.json ``chaos`` headline)."""
    from repro.launch.serve import serve_closed_loop

    g = graph(name)
    base = disjoint_requests(name)
    n = 12 if SMOKE else 24
    reqs = [dict(base[i % len(base)]) for i in range(n)]
    svc, tickets, rep = serve_closed_loop(
        g, reqs, concurrency=16, queue_cap=8, deadline_s=30.0)
    assert rep["completed"] + rep["shed"] + rep["timeouts"] == n, rep
    # bounded p99: the deadline is the latency ceiling — a completed
    # request can never have waited past it
    assert rep["p99_ms"] <= 30_000.0, rep
    return [{"bench": "chaos_overload", "graph": name, "n_queries": n,
             "overload_x": 2.0, **rep}]


# ------------------------------------------------- sharded per-shard fault
# Small/dense like bench_distributed's CFG: the point is ladder routing,
# not peel throughput.  Two far-apart window groups guarantee two pools,
# hence two independently built ShardedDegradationLadders.
_SHARDED_CFG = {"V": 64, "E": 192, "span": 128, "per_group": 4, "k": 2}

_SHARDED_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys; sys.path.insert(0, "src")
import json
import numpy as np, jax
from repro.core import ResilienceConfig, TCQService
from repro.core.faultinject import FaultPlan, FaultyStep
from repro.graphs import powerlaw_temporal

cfg = json.loads(sys.argv[1])
g = powerlaw_temporal(cfg["V"], cfg["E"], cfg["span"], seed=9)
lo, hi = g.span
third = max(2, (hi - lo) // 3)
reqs = []                       # two disjoint groups -> two pools/ladders
for base in (lo, lo + 2 * third):
    for i in range(cfg["per_group"]):
        reqs.append(dict(k=cfg["k"], ts=int(base + i),
                         te=int(min(base + third - i, hi))))


def digest(tickets):
    return [sorted((k, tuple(c.vertices.tolist()), c.n_edges)
                   for k, c in t.result.by_tti().items())
            for t in sorted(tickets, key=lambda t: t.id)]


mesh = jax.make_mesh((8, 1), ("data", "model"))   # lane-only: kernel rung up


def drain(wrapper):
    svc = TCQService(g, mesh=mesh, use_kernel=True, cache=False,
                     retain_snapshots=False,
                     resilience=ResilienceConfig(seed=0,
                                                 rung_wrapper=wrapper))
    for r in reqs:
        svc.submit(dict(r))
    out = svc.run_until_idle()
    return svc, digest(out)


_, want = drain(None)                              # fault-free sharded ref

state = {"armed": True}


def one_shot(name, fn):
    # ladders are built per window pool, so arming exactly one pallas
    # rung faults exactly one pool's shards — the per-shard fault
    if name == "pallas" and state["armed"]:
        state["armed"] = False
        return FaultyStep(fn, FaultPlan(fail_at=(0,)))
    return fn


svc, got = drain(one_shot)
evs = svc.engine.resilience_events()
demo = [e for e in evs if e.get("reason") == "error"]
assert not state["armed"], "fault never armed: no pallas rung was built"
assert len(demo) == 1, f"expected exactly one demotion, got {evs}"
assert got == want, "sharded drain diverged after per-shard rung fault"
backends = [p.get("backend") for p in svc.pool_log]
print("ROWS::" + json.dumps([{
    "bench": "chaos", "scenario": "sharded_rung_fault",
    "graph": "powerlaw64", "seed": 0, "devices": 8, "mesh": "8x1",
    "n_queries": len(reqs), "pools": len(svc.pool_log),
    "pool_backends": backends, "demotions": len(demo),
    "reason": "error", "equivalent": True}]))
"""


def run_sharded_fault() -> list:
    """Scenario 8 (subprocess: jax pins the device count at first init):
    one pool's Pallas rung faults on an 8-device lane-sharded mesh; only
    that pool's ladder demotes, the drain stays bit-identical."""
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_WORKER, json.dumps(_SHARDED_CFG)],
        capture_output=True, text=True, timeout=1800,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    if out.returncode != 0:
        raise RuntimeError("sharded_rung_fault worker failed:\n"
                           + out.stderr[-3000:])
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("ROWS::")][-1]
    return json.loads(line[len("ROWS::"):])


# --------------------------------------------------- kill-anywhere drill
def _durability_ops(name: str):
    """The drill's deterministic op tape: admissions, a same-tick
    submit+cancel twin of the first request (pinned to epoch 0, before
    any ingest — if a crash lands *between* the submit and the cancel
    records, recovery legitimately runs the twin to completion and its
    result must equal the first request's), two ingest batches, and a
    mid-tape checkpoint."""
    g = graph(name)
    reqs = [dict(r) for r in disjoint_requests(name)]
    rng = np.random.default_rng(1234)
    V = int(g.num_vertices)
    uts = g.unique_ts
    lo, hi = int(uts[0]), int(uts[-1])

    def batch(n):
        u = rng.integers(0, V, size=n)
        v = (u + 1 + rng.integers(0, V - 1, size=n)) % V   # never self-loop
        t = rng.integers(lo, hi + 1, size=n)
        return (u.astype(np.int64), v.astype(np.int64), t.astype(np.int64))

    ops = [("submit", dict(reqs[0])),
           ("submit_cancel", dict(reqs[0]))]       # epoch-0 twin of reqs[0]
    ops += [("submit", dict(r)) for r in reqs[1:4]]
    ops += [("edges", batch(24)), ("checkpoint",)]
    ops += [("submit", dict(r)) for r in reqs[4:8]]
    ops += [("edges", batch(12))]
    return ops


def _drive_ops(svc, ops, tickets=None):
    """Apply one tape entry per poll tick while pools drain.  ``tickets``
    (id -> ticket) is filled *as submits land*, so a caller catching
    :class:`InjectedCrash` still sees everything admitted pre-crash."""
    tickets = {} if tickets is None else tickets
    state = {"i": 0}

    def poll(s):
        if state["i"] >= len(ops):
            return
        op = ops[state["i"]]
        state["i"] += 1
        if op[0] == "submit":
            tk = s.submit(dict(op[1]))
            tickets[tk.id] = tk
        elif op[0] == "submit_cancel":
            tk = s.submit(dict(op[1]))
            tickets[tk.id] = tk
            s.cancel(tk)
        elif op[0] == "edges":
            s.push_edges(*op[1])
        elif op[0] == "checkpoint" and s.wal is not None:
            s.checkpoint()

    while state["i"] < len(ops) or svc.pending:
        svc.run_until_idle(poll)
    return tickets


def _journal_roster(wal_dir):
    """Every record on disk, in replay order (asserts no torn tail)."""
    from repro.core import wal as walmod

    roster = []
    for seq, path in walmod.list_segments(wal_dir):
        recs, tail, _ = walmod.read_segment(path)
        assert tail is None, (path, tail)
        roster.extend(recs)
    return roster


def _fingerprints(g0, roster):
    """Expected graph fingerprint after each journal-record prefix."""
    fps, g = [], g0
    for rec in roster:
        if rec.kind == "edges":
            g = g.add_edges(rec.arrays["u"], rec.arrays["v"],
                            rec.arrays["t"])
        fps.append(g.fingerprint())
    return fps


def _gate_recovery(rec_svc, prefix, precrash, ref_by_id, ref_twin,
                   want_fp, ctx):
    """The drill's contract for one surviving journal prefix: recovery +
    drain must account for *every* admission in the prefix (resolved
    pre-crash or re-queued — never lost), every result bit-identical to
    the fault-free reference, and the recovered graph fingerprint equal
    to the prefix's expected lineage."""
    got = {tk.id: tk for tk in rec_svc.run_until_idle()}
    fp = rec_svc.graph.fingerprint()
    assert fp == want_fp, (ctx, fp, want_fp)
    cancelled = {int(r.meta["id"]) for r in prefix if r.kind == "cancel"}
    checked = 0
    for r in prefix:
        if r.kind != "submit":
            continue
        rid = int(r.meta["id"])
        tk = got.get(rid)
        if tk is None:                       # resolved before the crash
            tk = precrash.get(rid)
            assert tk is not None and tk.done, \
                f"durability[{ctx}]: journaled admission #{rid} was lost"
        if rid in cancelled:
            assert tk.status == "cancelled", (ctx, rid, tk.status)
            continue
        want = ref_by_id[rid]
        if want.status == "cancelled":
            # the cancel record fell off the surviving tail: the
            # recovered ticket runs to completion — its result must
            # match the reference twin with the same request + epoch pin
            want = ref_twin[(tk.k, tk.h, tk.ts, tk.te, tk.epoch)]
        assert_cores_equal(tk.result, want.result,
                           ctx=f"durability[{ctx}] id#{rid}")
        checked += 1
    return checked


def run_durability(name: str = "collegemsg"):
    """Kill-anywhere durability drill: crash the service after *every*
    journal record (every prefix when ``REPRO_CHAOS``/full bench;
    representative points in SMOKE), plus mid-checkpoint
    (rotation-before-snapshot), torn-tail, flipped-byte and
    corrupt-newest-snapshot post-mortems — recovery + drain must be
    bit-identical to the uninterrupted run over each surviving prefix.
    Emits the recovery-time vs journal-tail-length curve."""
    from repro.core import TCQService
    from repro.core import wal as walmod
    from repro.core.faultinject import (CrashingWAL, InjectedCrash,
                                        corrupt_snapshot, flip_tail_byte,
                                        torn_tail)

    g = graph(name)
    ops = _durability_ops(name)
    rows = []

    # fault-free reference: same tape, no journal
    ref_by_id = _drive_ops(TCQService(g), ops)
    ref_twin = {(tk.k, tk.h, tk.ts, tk.te, tk.epoch): tk
                for tk in ref_by_id.values() if tk.status == "done"}

    # the uninterrupted journaled run: its directory is the post-mortem
    # mutilation target, its journal the kill-point roster
    tmp = tempfile.mkdtemp(prefix="tcq-durability-")
    try:
        full_dir = os.path.join(tmp, "full")
        svc = TCQService(g, wal_dir=full_dir, fsync="always")
        full = _drive_ops(svc, ops)
        for rid, tk in full.items():
            if tk.status == "done":
                assert_cores_equal(tk.result, ref_by_id[rid].result,
                                   ctx=f"durability[journaled] id#{rid}")
        svc.wal.close()
        roster = _journal_roster(full_dir)
        fps = _fingerprints(g, roster)
        R = len(roster)
        sig = [(r.kind, (r.meta or {}).get("id")) for r in roster]

        def kill_at(n):
            """Fresh run killed right after record ``n`` lands, then
            recover + gate the n+1-record prefix."""
            d = os.path.join(tmp, f"kill-{n}")
            killer = CrashingWAL(walmod.WriteAheadLog(d, fsync="always"),
                                 crash_after_records=n)
            crash_svc = TCQService(g, wal=killer)
            seen = {}
            try:
                _drive_ops(crash_svc, ops, seen)
                raise AssertionError(f"crash point {n} never fired")
            except InjectedCrash:
                pass
            prefix = _journal_roster(d)
            got_sig = [(r.kind, (r.meta or {}).get("id")) for r in prefix]
            assert got_sig == sig[:n + 1], (n, got_sig, sig[:n + 1])
            rec = TCQService.recover(d)
            rep = rec.recovery_report
            checked = _gate_recovery(rec, prefix, seen, ref_by_id,
                                     ref_twin, fps[n], f"kill@{n}")
            rec.wal.close()
            return {"bench": "durability", "scenario": "kill",
                    "graph": name, "crash_after_record": n,
                    "tail_records": rep["wal_records"],
                    "snapshot_seq": rep["snapshot_seq"],
                    "requeued": rep["pending_after"],
                    "results_checked": checked,
                    "recover_s": rep["recover_s"], "equivalent": True}

        # every prefix on the full sweep; SMOKE samples the boundary
        # cases (first record, around the first ingest + the checkpoint,
        # the final record)
        points = list(range(R))
        if SMOKE and not CHAOS:
            e0 = next(i for i, r in enumerate(roster) if r.kind == "edges")
            points = sorted({0, 1, e0, min(e0 + 1, R - 1), R - 1})
        for n in points:
            rows.append(kill_at(n))

        def post_mortem(scenario, mutilate, prefix_len, *, tail_reason=None,
                        snapshots_skipped=0):
            """Mutilate a copy of the completed run's journal dir, then
            recover + gate the surviving prefix."""
            d = os.path.join(tmp, scenario)
            shutil.copytree(full_dir, d)
            mutilate(d)
            rec = TCQService.recover(d)
            rep = rec.recovery_report
            if tail_reason is not None:
                reasons = [e["reason"] for e in rep["tail_events"]]
                assert reasons == [tail_reason], (scenario, rep)
            assert len(rep["snapshots_skipped"]) == snapshots_skipped, rep
            checked = _gate_recovery(rec, roster[:prefix_len], full,
                                     ref_by_id, ref_twin,
                                     fps[prefix_len - 1], scenario)
            rec.wal.close()
            rows.append({"bench": "durability", "scenario": scenario,
                         "graph": name,
                         "tail_records": rep["wal_records"],
                         "tail_events": rep["tail_events"],
                         "snapshots_skipped":
                             len(rep["snapshots_skipped"]),
                         "results_checked": checked,
                         "recover_s": rep["recover_s"],
                         "equivalent": True})

        # torn tail: the last record is half-written at power loss — it
        # was never acknowledged, so the prefix simply ends one earlier
        post_mortem("torn_tail", torn_tail, R - 1, tail_reason="torn")
        # bit rot inside the last record: CRC catches it, same cut
        post_mortem("flipped_byte", flip_tail_byte, R - 1,
                    tail_reason="corrupt")
        # corrupt newest snapshot: fall back to the previous retained
        # checkpoint and replay its (longer) tail — nothing is lost
        post_mortem("corrupt_snapshot", corrupt_snapshot, R,
                    snapshots_skipped=1)

        # mid-checkpoint crash: dies after the rotation seals the old
        # segment, before the snapshot lands; a junk .tmp (a snapshot
        # save that died mid-write) is strewn in for good measure
        d = os.path.join(tmp, "mid-checkpoint")
        killer = CrashingWAL(walmod.WriteAheadLog(d, fsync="always"),
                             crash_on_rotate=True)
        crash_svc = TCQService(g, wal=killer)
        seen = {}
        try:
            _drive_ops(crash_svc, ops, seen)
            raise AssertionError("rotate crash never fired")
        except InjectedCrash:
            pass
        with open(os.path.join(d, "snapshot-99999999.npz.tmp"), "wb") as f:
            f.write(b"half a snapshot")
        prefix = _journal_roster(d)
        n = len(prefix)
        assert [(r.kind, (r.meta or {}).get("id")) for r in prefix] \
            == sig[:n], "pre-rotation journal diverged"
        rec = TCQService.recover(d)
        rep = rec.recovery_report
        checked = _gate_recovery(rec, prefix, seen, ref_by_id, ref_twin,
                                 fps[n - 1], "mid_checkpoint")
        ck = rec.checkpoint()            # GC sweeps the junk .tmp
        assert not os.path.exists(os.path.join(
            d, "snapshot-99999999.npz.tmp")), "stray .tmp survived GC"
        rec.wal.close()
        rows.append({"bench": "durability", "scenario": "mid_checkpoint",
                     "graph": name, "tail_records": rep["wal_records"],
                     "results_checked": checked,
                     "recover_s": rep["recover_s"],
                     "gc_removed": ck["gc_removed"], "equivalent": True})
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    rows.append({"bench": "durability", "scenario": "summary",
                 "graph": name, "journal_records": R,
                 "kill_points": len(points),
                 "max_recover_s": max(r["recover_s"] for r in rows
                                      if "recover_s" in r),
                 "equivalent": True})
    return rows


def run(name: str = "collegemsg"):
    rows = []
    for seed in SEEDS:
        rows += run_scenarios(name, seed)
    rows += run_sharded_fault()
    rows += run_overload(name)
    emit("bench_chaos", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
    for r in run_durability():
        print(r)
