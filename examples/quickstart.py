"""Quickstart: temporal k-core queries on a paper-style micro graph.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import TCQEngine, brute_force_query
from repro.graphs import paper_style_example


def main():
    g = paper_style_example()
    print(f"graph: |V|={g.num_vertices} |E|={g.num_edges} "
          f"pairs={g.num_pairs} span={g.span}")

    eng = TCQEngine(g)

    # the paper's flagship query: ALL distinct 2-cores in any subinterval
    res = eng.query(k=2, Ts=1, Te=8)
    print(f"\nTCQ(k=2, [1,8]) -> {len(res)} distinct temporal 2-cores "
          f"(evaluated {res.stats.cells_evaluated}/"
          f"{res.stats.cells_total} cells, "
          f"pruned {res.stats.pruned_pct():.0f}%):")
    for c in sorted(res.cores, key=lambda c: c.tti):
        print(f"  TTI=[{c.tti[0]},{c.tti[1]}]  V={sorted(c.vertices.tolist())}"
              f"  |E|={c.n_edges}")

    # sanity: identical to brute force over every subinterval
    oracle = brute_force_query(g, 2, 1, 8)
    assert set(c.tti for c in res.cores) == set(oracle.keys())
    print("\nmatches the brute-force oracle ✓")

    # §6.2 extensions: link strength and time-span constraints
    strong = eng.query(k=2, Ts=1, Te=8, h=2)
    short = eng.query(k=2, Ts=1, Te=8, max_span=2)
    print(f"link-strength h=2 -> {len(strong)} cores;"
          f" span<=2 -> {len(short)} cores "
          f"{sorted(c.tti for c in short.cores)}")

    # historical k-core (the paper's Def. 1 special case) = top core
    top = max(res.cores, key=lambda c: c.n_edges)
    print(f"historical 2-core of [1,8] = core with TTI {top.tti}, "
          f"|V|={top.n_vertices}")


if __name__ == "__main__":
    main()
