"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE two lines below must run before any other import (jax locks the device
count at first initialization):
"""
import os  # noqa: E402

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_config, get_smoke_config, list_archs  # noqa: E402
from repro.launch import analysis  # noqa: E402
from repro.launch import shapes as S  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")


def _chips(mesh) -> int:
    return int(mesh.devices.size)


def lower_cell(arch: str, shape: str, multi_pod: bool, smoke: bool = False,
               overrides: dict = None):
    """Lower + compile one cell; returns (record, compiled).
    overrides: ModelConfig field replacements (perf hillclimb A/Bs)."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if overrides:
        cfg = cfg.scaled(**overrides)
    cell = S.SHAPES[shape]
    if smoke:
        cell = S.ShapeCell(cell.name, min(cell.seq, 128),
                           min(cell.batch, 8), cell.kind)
    ok, why = S.cell_is_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "skipped": True,
                "reason": why}, None
    mesh = make_production_mesh(multi_pod=multi_pod)
    batch_abs, batch_ps = S.batch_specs(cfg, cell, mesh)
    batch_ns = steps.ns(mesh, batch_ps)
    t0 = time.perf_counter()
    with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") \
            else mesh:
        if cell.kind == "train":
            n_micro = S.microbatches(cfg, cell, mesh)
            _, jit_with, p_ns, o_ns, opt = steps.build_train_step(
                cfg, mesh, n_micro)
            from repro.models import transformer as T

            params_abs = T.abstract_params(cfg)
            opt_abs = opt.init_abstract(params_abs)
            jitted = jit_with(batch_ns)
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif cell.kind == "prefill":
            _, jit_with, p_ns = steps.build_prefill_step(cfg, mesh, cell)
            from repro.models import transformer as T

            params_abs = T.abstract_params(cfg)
            jitted = jit_with(batch_ns)
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            _, jit_with, p_ns, cache_abs, c_ns = steps.build_serve_step(
                cfg, mesh, cell)
            from repro.models import transformer as T

            params_abs = T.abstract_params(cfg)
            jitted = jit_with(batch_ns)
            lowered = jitted.lower(params_abs, cache_abs, batch_abs)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # some backends lack memory analysis
        mem_rec = {"error": str(e)}
    hlo = compiled.as_text()
    from repro.launch.hlo_cost import HLOCost

    hc = HLOCost(hlo)
    chips = _chips(mesh)
    tokens = cell.batch * (cell.seq if cell.kind != "decode" else 1)
    # MODEL_FLOPS = 6·N·D exactly (the brief's definition): it already
    # bakes in the fwd+bwd convention, so train gets no extra factor and
    # inference cells are EXPECTED to show useful_compute_ratio ≈ 3
    # (forward-only does a third of 6·N·D).
    mf = cfg.model_flops(tokens)
    terms = analysis.roofline_terms(
        {"flops": hc.flops, "bytes accessed": hc.bytes}, hc.collective_ops(),
        model_flops_per_device=mf / chips)
    # raw XLA numbers kept as a cross-check (they omit loop trip counts)
    terms["xla_cost_analysis_flops"] = float(cost.get("flops", 0.0))
    record = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "smoke": smoke,
        "kind": cell.kind, "seq": cell.seq, "batch": cell.batch,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "optimizer": cfg.optimizer,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem_rec,
        "collectives": hc.collective_summary(),
        "roofline": terms,
    }
    return record, compiled


def lower_tcq_cell(name: str, multi_pod: bool, combine: str = "rs_ag",
                   wave: int = None):
    """Lower one distributed-TCQ engine cell (single peel iteration = the
    roofline unit; iteration counts come from the CPU benchmarks)."""
    import dataclasses

    from repro.configs import get_tcq_config
    from repro.core import distributed as D

    cfg = get_tcq_config(name)
    if wave:
        cfg = dataclasses.replace(cfg, wave=wave)
    mesh = make_production_mesh(multi_pod=multi_pod)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes["model"]
    tel = D.abstract_sharded_tel(cfg.num_vertices, cfg.num_edges,
                                 cfg.num_pairs, m)
    sh = D.wave_shardings(mesh, tel.num_vertices, m)
    q = cfg.wave
    alive = jax.ShapeDtypeStruct((q, tel.num_vertices), jnp_bool())
    lane = jax.ShapeDtypeStruct((q,), jnp_i32())
    scalar = jax.ShapeDtypeStruct((), jnp_i32())
    step = build_tcq_step(mesh, tel, combine)
    t0 = time.time()
    lowered = jax.jit(step, in_shardings=(
        (sh["edges"],) * 6 + (sh["alive"], sh["lane"], sh["lane"],
                              sh["scalar"], sh["scalar"]))).lower(
        *( (tel.src, tel.dst, tel.t, tel.pair_local, tel.hp_src,
            tel.hp_pair) + (alive, lane, lane, scalar, scalar)))
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    from repro.launch.hlo_cost import HLOCost

    hc = HLOCost(compiled.as_text())
    e_s = tel.src.shape[1]
    p_s = tel.num_pairs_shard
    q_loc = max(1, q // (mesh.devices.size // m))
    v = tel.num_vertices
    # intrinsic per-iteration streaming of the algorithm (per device)
    useful = (e_s * 16                      # edge arrays read once
              + 2 * q_loc * e_s * 1        # edge-activity bools r/w
              + 2 * p_s * q_loc * 4        # pair counts w+r
              + 2 * 2 * p_s * q_loc * 4    # half-pair contributions
              + v * q_loc * 4)             # degree write
    terms = analysis.roofline_terms(
        {"flops": hc.flops, "bytes accessed": hc.bytes},
        hc.collective_ops())
    terms["useful_bytes_per_device"] = useful
    terms["min_traffic_fraction"] = (
        useful / analysis.HBM_BW / terms["bound_step_time_s"]
        if terms["bound_step_time_s"] else 0.0)
    try:
        mem = compiled.memory_analysis()
        mem_rec = {"temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                   "argument_bytes": getattr(mem, "argument_size_in_bytes",
                                             None)}
    except Exception as e:  # pragma: no cover
        mem_rec = {"error": str(e)}
    return {
        "arch": name, "shape": f"wave{q}", "kind": "tcq",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(mesh.devices.size), "combine": combine,
        "V": cfg.num_vertices, "E": cfg.num_edges, "P": cfg.num_pairs,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem_rec, "collectives": hc.collective_summary(),
        "roofline": terms,
    }


def jnp_bool():
    import jax.numpy as jnp

    return jnp.bool_


def jnp_i32():
    import jax.numpy as jnp

    return jnp.int32


def build_tcq_step(mesh, tel, combine):
    from repro.core import distributed as D

    return D.build_wave_step(mesh, num_vertices=tel.num_vertices,
                             combine=combine, p_s=tel.num_pairs_shard,
                             single_iteration=True)


def run_tcq_cells(names, meshes, combines=("psum", "rs_ag"),
                  out_dir=RESULTS_DIR):
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for name in names:
        for mesh_name in meshes:
            for combine in combines:
                multi = mesh_name == "multi"
                tag = (f"{name}__wave__{'2x16x16' if multi else '16x16'}"
                       f"__{combine}")
                path = os.path.join(out_dir, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] cached {tag}")
                    with open(path) as f:
                        results.append(json.load(f))
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec = lower_tcq_cell(name, multi, combine)
                except Exception:
                    rec = {"arch": name, "failed": True,
                           "error": traceback.format_exc()[-2000:]}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                results.append(rec)
                status = ("FAIL" if rec.get("failed") else
                          f"ok ({rec['compile_s']}s, "
                          f"dom={rec['roofline']['dominant']})")
                print(f"[dryrun] {tag}: {status}", flush=True)
    return results


def run_cells(archs, shapes, meshes, smoke=False, out_dir=RESULTS_DIR):
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                multi = mesh_name == "multi"
                tag = f"{arch}__{shape}__{'2x16x16' if multi else '16x16'}"
                path = os.path.join(out_dir, tag + ".json")
                if os.path.exists(path) and not smoke:
                    print(f"[dryrun] cached {tag}")
                    with open(path) as f:
                        results.append(json.load(f))
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec, _ = lower_cell(arch, shape, multi, smoke)
                except Exception:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "failed": True,
                           "error": traceback.format_exc()[-2000:]}
                if not smoke:
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                results.append(rec)
                status = ("SKIP" if rec.get("skipped") else
                          "FAIL" if rec.get("failed") else
                          f"ok ({rec['compile_s']}s compile, "
                          f"dom={rec['roofline']['dominant']})")
                print(f"[dryrun] {tag}: {status}", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, comma list, or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (CI self-test)")
    ap.add_argument("--tcq", default="",
                    help="TCQ engine configs ('all' or comma list); "
                         "replaces the LM sweep when set")
    ap.add_argument("--combine", default="psum,rs_ag")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    if args.tcq:
        from repro.configs import list_tcq_configs

        names = (list_tcq_configs() if args.tcq == "all"
                 else args.tcq.split(","))
        results = run_tcq_cells(names, meshes,
                                combines=tuple(args.combine.split(",")),
                                out_dir=args.out)
    else:
        archs = list_archs() if args.arch == "all" else args.arch.split(",")
        shapes = (list(S.SHAPES) if args.shape == "all"
                  else args.shape.split(","))
        results = run_cells(archs, shapes, meshes, smoke=args.smoke,
                            out_dir=args.out)
    n_ok = sum(1 for r in results if not r.get("failed")
               and not r.get("skipped"))
    n_skip = sum(1 for r in results if r.get("skipped"))
    n_fail = sum(1 for r in results if r.get("failed"))
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped (recorded), "
          f"{n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
