"""Distributed TCQ engine: shard_map semantics on degenerate + subprocess
multi-device meshes, plan invariants, and both degree-combine variants."""

import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.distributed import DistributedTCQ, shard_graph
from repro.core.oracle import peel_window
from repro.graphs import planted_cores, powerlaw_temporal


def _check_engine(g, mesh, combine, k, cells):
    eng = DistributedTCQ(g, mesh, combine=combine)
    ts = [c[0] for c in cells]
    te = [c[1] for c in cells]
    alive, lo, hi, ne, iters = eng.query_wave(ts, te, k)
    for i, (a, b) in enumerate(cells):
        em = peel_window(g, a, b, k)
        verts = (set(np.unique(np.concatenate(
            [g.src[em], g.dst[em]])).tolist()) if em.any() else set())
        got = set(np.flatnonzero(
            np.asarray(alive[i])[:g.num_vertices]).tolist())
        assert got == verts, (combine, i)
        if em.any():
            assert (int(lo[i]), int(hi[i])) == (int(g.t[em].min()),
                                                int(g.t[em].max()))
            assert int(ne[i]) == int(em.sum())


@pytest.mark.parametrize("combine", ["psum", "rs_ag"])
def test_wave_on_unit_mesh(combine):
    g = planted_cores(seed=3)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    _check_engine(g, mesh, combine, 3, [(1, 40), (5, 30), (10, 20), (1, 15)])


def test_pair_aligned_sharding_invariants():
    g = powerlaw_temporal(80, 600, 50, seed=1)
    for m in (2, 4, 8):
        plan = shard_graph(g, m)
        assert plan.src.shape[0] == m
        # every real edge appears exactly once; sentinels are inert
        real = plan.t >= 0
        assert int(real.sum()) == g.num_edges
        # pair-locality: local pair ids within [0, P_s)
        assert int(plan.pair_local[real].max()) < plan.num_pairs_shard
        # padded vertex space divisible by m
        assert plan.num_vertices % m == 0


_SUBPROCESS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys; sys.path.insert(0, "src")
import numpy as np, jax
from repro.core.distributed import DistributedTCQ
from repro.core.oracle import peel_window
from repro.graphs import planted_cores
g = planted_cores(seed=3)
mesh = jax.make_mesh((2, 4), ("data", "model"))
for combine in ("psum", "rs_ag"):
    eng = DistributedTCQ(g, mesh, combine=combine)
    ts, te, k = [1, 5, 10, 1], [40, 30, 20, 15], 3
    alive, lo, hi, ne, it = eng.query_wave(ts, te, k)
    for i in range(4):
        em = peel_window(g, ts[i], te[i], k)
        verts = set(np.unique(np.concatenate([g.src[em], g.dst[em]])).tolist()) if em.any() else set()
        got = set(np.flatnonzero(np.asarray(alive[i])[:g.num_vertices]).tolist())
        assert got == verts, (combine, i)
print("OK")
"""


def test_wave_on_2x4_mesh_subprocess():
    """Real multi-device shard_map semantics (8 fake CPU devices require a
    fresh process: jax locks the device count at first init)."""
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS],
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_dryrun_smoke_subprocess():
    """The dry-run entrypoint itself (reduced configs, real 512-device mesh
    construction) — proves the mesh + lowering pipeline end to end."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
         "--arch", "gemma2-2b", "--shape", "train_4k,decode_32k",
         "--mesh", "both"],
        capture_output=True, text=True, cwd="/root/repo", timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"})
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-1500:])
    assert "0 failed" in out.stdout
