"""Multi-tenant scheduler: QueryState pool, mixed-(k, h) batches, the
incremental empty-cell staircase, lane autotuning, and the LRU window
cache.

The load-bearing property: ``query_batch`` over heterogeneous
k/h/window requests — including empty-result and single-timestamp
windows — returns results *identical* (TTI keys, vertex sets, n_edges)
to per-query ``mode="serial"`` runs, at any slot-ring depth.
"""

import numpy as np
import pytest

from repro.core import TCQEngine, TemporalGraph
from repro.core.scheduler import EmptyStaircase, QueryState, autotune_wave


def random_graph(seed: int, n_v: int = 20, n_e: int = 120, max_t: int = 16):
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n_v, n_e)
    v = rng.integers(0, n_v, n_e)
    t = rng.integers(1, max_t + 1, n_e)
    return TemporalGraph.from_edges(u, v, t, num_vertices=n_v)


def assert_same(got, want, ctx=""):
    assert got.by_tti().keys() == want.by_tti().keys(), ctx
    for key, cw in want.by_tti().items():
        cg = got.by_tti()[key]
        assert np.array_equal(cg.vertices, cw.vertices), (ctx, key)
        assert cg.n_edges == cw.n_edges, (ctx, key)
        assert cg.k == cw.k, (ctx, key)


# ------------------------------------------------------------ batch = serial
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_query_batch_mixed_kh_equals_serial(seed):
    g = random_graph(seed, n_v=22, n_e=160, max_t=18)
    Ts, Te = g.span
    mid = (Ts + Te) // 2
    ut0 = int(g.unique_ts[0])
    reqs = [
        {"k": 2, "ts": Ts, "te": Te},                   # full window
        {"k": 3, "ts": Ts, "te": mid},                  # half window
        {"k": 2, "ts": mid, "te": Te, "h": 2},          # link-strength
        {"k": 4, "ts": Ts + 1, "te": Te - 1},           # higher k
        {"k": 1, "ts": mid - 2, "te": mid + 2},         # tiny window
        {"k": 30, "ts": Ts, "te": Te},                  # empty result
        {"k": 2, "ts": ut0, "te": ut0},                 # single timestamp
        {"k": 2, "ts": Te + 10, "te": Te + 20},         # empty schedule
    ]
    eng = TCQEngine(g)
    outs = eng.query_batch(reqs)
    assert len(outs) == len(reqs)
    for r, out in zip(reqs, outs):
        want = eng.query(r["k"], r["ts"], r["te"], h=r.get("h", 1))
        assert_same(out, want, ctx=str(r))
        assert out.stats.batch_size == len(reqs)


@pytest.mark.parametrize("depth,wave", [(1, 4), (3, 8), (4, "auto")])
def test_query_batch_depth_ring(depth, wave):
    g = random_graph(7, n_v=18, n_e=130, max_t=12)
    Ts, Te = g.span
    reqs = [{"k": 2, "ts": Ts, "te": Te},
            {"k": 3, "ts": Ts, "te": (Ts + Te) // 2},
            {"k": 2, "ts": (Ts + Te) // 2, "te": Te, "h": 2}]
    eng = TCQEngine(g)
    outs = eng.query_batch(reqs, wave=wave, depth=depth)
    for r, out in zip(reqs, outs):
        want = eng.query(r["k"], r["ts"], r["te"], h=r.get("h", 1))
        assert_same(out, want, ctx=f"depth={depth} {r}")


def test_query_batch_occupancy_and_shared_stats():
    g = random_graph(9, n_v=24, n_e=200, max_t=20)
    Ts, Te = g.span
    reqs = [{"k": 2, "ts": Ts, "te": Te} for _ in range(4)]
    outs = TCQEngine(g).query_batch(reqs, wave=8)
    s0 = outs[0].stats
    assert s0.device_steps > 0
    assert 0.0 < s0.occupancy <= 8.0
    # pipeline counters are batch-wide: identical on every member query
    for out in outs[1:]:
        assert out.stats.device_steps == s0.device_steps
        assert out.stats.occupancy == s0.occupancy
    # identical queries still retire with identical (deduped) results
    for out in outs[1:]:
        assert_same(out, outs[0])


def test_single_query_wave_depths_equal():
    g = random_graph(4, n_v=20, n_e=150, max_t=16)
    Ts, Te = g.span
    eng = TCQEngine(g)
    want = eng.query(2, Ts, Te)
    for depth in (1, 2, 4):
        got = eng.query(2, Ts, Te, mode="wave", wave=5, depth=depth)
        assert_same(got, want, ctx=f"depth={depth}")


# --------------------------------------------------------- serial windowing
def test_serial_mode_uses_windowed_tel():
    g = random_graph(13, n_v=20, n_e=200, max_t=24)
    Ts, Te = g.span
    lo, hi = Ts + (Te - Ts) // 4, Ts + (3 * (Te - Ts)) // 4
    eng = TCQEngine(g)
    res = eng.query(2, lo, hi)
    # the stat reports the truncated edge count, strictly below |E|
    n_in_window, _ = g.window_counts(lo, hi)
    assert res.stats.window_edges == n_in_window < g.num_edges
    assert eng._win_cache       # truncation was built and cached
    # and the truncated peel returns exactly the wave pipeline's results
    assert_same(eng.query(2, lo, hi, mode="wave"), res)


# ------------------------------------------------------------- LRU window
def test_window_cache_is_lru(monkeypatch):
    from repro.core import otcd

    g = random_graph(17, n_v=16, n_e=140, max_t=30)
    Ts, Te = g.span
    eng = TCQEngine(g)
    monkeypatch.setattr(otcd, "_WINDOW_CACHE_MAX", 2)
    eng.query(2, Ts, Te - 10)           # A
    eng.query(2, Ts, Te - 12)           # B
    key_a = (eng.epoch, Ts, Te - 10)    # cache keys are epoch-qualified
    assert key_a in eng._win_cache
    eng.query(2, Ts, Te - 10)           # touch A -> back of the queue
    eng.query(2, Ts, Te - 14)           # C evicts B (least recent), not A
    assert key_a in eng._win_cache
    assert (eng.epoch, Ts, Te - 12) not in eng._win_cache
    assert (eng.epoch, Ts, Te - 14) in eng._win_cache


# ----------------------------------------------------------- EmptyStaircase
def test_empty_staircase_matches_naive_scan():
    rng = np.random.default_rng(0)
    for _ in range(50):
        marks = []
        stair = EmptyStaircase()
        for _ in range(rng.integers(1, 40)):
            i = int(rng.integers(0, 30))
            j = int(rng.integers(0, 30))
            marks.append((i, j))
            stair.add(i, j)
            for r in range(-1, 31):
                naive = max((je for ie, je in marks if ie <= r), default=-1)
                assert stair.bound(r) == naive, (marks, r)


def test_empty_staircase_dominance_keeps_corner_list_small():
    stair = EmptyStaircase()
    stair.add(5, 10)
    stair.add(7, 3)         # dominated: bound unchanged everywhere
    assert len(stair) == 1
    stair.add(5, 12)        # replaces same-row mark
    assert len(stair) == 1 and stair.bound(5) == 12
    stair.add(2, 20)        # dominates (5, 12)
    assert len(stair) == 1 and stair.bound(30) == 20
    stair.add(10, 25)       # genuine new corner
    assert len(stair) == 2
    assert stair.bound(9) == 20 and stair.bound(10) == 25
    assert stair.bound(1) == -1


# -------------------------------------------------------------- autotuning
def test_autotune_wave_properties():
    for v, e, q in [(10, 100, 1), (1_800, 4_096, 1), (1_800, 4_096, 8),
                    (100_000, 1 << 20, 4), (5, 0, 100)]:
        w = autotune_wave(v, e, num_queries=q)
        assert 4 <= w <= 64
        assert w & (w - 1) == 0, "lane count must be a power of two"
    # more concurrent queries never shrink the pool
    assert (autotune_wave(1_800, 4_096, num_queries=8)
            >= autotune_wave(1_800, 4_096, num_queries=1))
    # huge per-lane footprints clamp the pool down
    assert autotune_wave(10_000_000, 1 << 24) == 4


# --------------------------------------------------- QueryState bookkeeping
def test_query_state_claim_and_drain():
    from repro.core.results import QueryStats

    uts = np.arange(5)
    qs = QueryState(uts, k=2, h=1, prune=True, stats=QueryStats())
    rows = []
    while True:
        row = qs.claim()
        if row is None:
            break
        rows.append(row)
    assert [r.i for r in rows] == [0, 1, 2, 3, 4]
    assert all(r.j == 4 for r in rows)
    assert qs.drained and not qs.done and qs.live_rows == 5
    # an empty cell retires the row and feeds the staircase
    kept = qs.retire(rows[0], 0, 0, 0, None, lambda: None)
    assert not kept and qs.empty.bound(0) == 4
