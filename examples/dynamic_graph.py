"""Dynamic temporal graph (paper §6.1 + §7.4 case-study flavor): stream
edge batches into the TEL and watch a community grow across re-queries —
the bursting-community analysis of the paper's Fig. 15 — now on the
streaming service runtime: each arrival batch is an *incremental*
merge-append producing a new epoch (no engine rebuild, no full re-sort),
and queries submitted after a push see the new edges while queries
admitted before it stay pinned to their snapshot.

Run:  PYTHONPATH=src python examples/dynamic_graph.py
"""

from repro.core import TCQService
from repro.graphs import EdgeStream, planted_cores


def main():
    g = planted_cores(num_vertices=80, k=3, n_cliques=5, clique_size=7,
                      time_span=60, noise_edges=150, seed=13)
    stream = EdgeStream()
    print("streaming the graph in 5 arrival batches; querying after each\n")
    svc = None
    prev_ttis = set()
    for i, (u, v, t) in enumerate(EdgeStream.replay(g, 5)):
        cur = stream.push(u, v, t)
        if svc is None:
            # first batch bootstraps the service; later epochs arrive via
            # the stream subscription (incremental merge-append, O(E+B))
            svc = TCQService(cur)
            svc.connect(stream)
        tk = svc.submit({"k": 3, "ts": 1, "te": 60})
        svc.run_until_idle()
        res = tk.result
        new = set(c.tti for c in res.cores) - prev_ttis
        prev_ttis |= new
        print(f"batch {i+1}: epoch={tk.epoch} |E|={cur.num_edges:5d} -> "
              f"{len(res):3d} cores ({len(new)} new)")
        # growth analysis: nested cores = community expansion (Fig. 15)
        chains = 0
        for c in res.cores:
            for c2 in res.cores:
                if (c2.tti[0] <= c.tti[0] and c.tti[1] <= c2.tti[1]
                        and c.n_vertices < c2.n_vertices
                        and set(c.vertices).issubset(set(c2.vertices))):
                    chains += 1
                    break
        print(f"          {chains} cores are nested inside a larger, "
              f"longer-lived core (growth chains)")
    top = sorted(res.cores, key=lambda c: -c.n_vertices)[:3]
    print("\nlargest communities at the end:")
    for c in top:
        print(f"  {c}")
    occ = [p["occupancy"] for p in svc.pool_log if p["device_steps"]]
    print(f"\nserved {len(svc.completed)} queries over {svc.epoch + 1} "
          f"epochs, {len(svc.pool_log)} pools, "
          f"mean occupancy {sum(occ) / max(1, len(occ)):.1f} cells/step")


if __name__ == "__main__":
    main()
